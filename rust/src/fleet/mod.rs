//! Fleet serving — the serving-scale axis on top of the per-design
//! toolflow (ROADMAP north star: heavy HAR traffic, not single clips).
//!
//! HARFLOW3D (§V) optimises one design for one clip's latency; serving
//! millions of users adds the dimensions the throughput-oriented
//! siblings (fpgaHART, FPGA-QHAR) optimise for: queueing, dispatch,
//! and fleet sizing. This module provides
//!
//! * a **deterministic event-driven simulator** over a fleet of FPGA
//!   boards, each serving one loaded design at a time with a per-board
//!   FIFO or priority queue, charging `sim::DesignLatencyProfile`
//!   service latency per clip and the design-switch (reconfiguration)
//!   cost when a board changes design — arrivals come from a seeded
//!   generator ([`arrivals::generate`]: Poisson, diurnal, flash-crowd
//!   or self-similar, optionally sharded across threads by
//!   [`arrivals::sharded`]) or a trace file ([`arrivals::from_trace`]),
//!   and every tie is broken by sequence number so a seed pins the run
//!   bit-for-bit. The event queue is a calendar (bucket) queue popping
//!   in exact `(t_ms, seq)` order — O(1) amortised against the heap's
//!   O(log n) — and board/request state lives in index-based SoA
//!   arrays with no per-event allocation;
//! * **clip batching** ([`BatchCfg`]): up to `max_batch` queued clips
//!   of the same model run as one invocation sequence, paying the
//!   pipeline fill once ([`ServiceProfile::batch_ms`]); an idle board
//!   may hold the head clip up to `max_wait_ms` for batchmates;
//! * an **SLO-driven capacity planner** ([`planner::plan`]) that
//!   consumes `report::sweep` design points and searches board counts
//!   × design assignments — homogeneous per device type and, when
//!   enabled, heterogeneous mixed-device compositions — for the
//!   cheapest fleet meeting a p99 SLO at a target arrival rate;
//! * **fault injection and resilience** ([`faults`]): deterministic
//!   board crash/recover cycles, straggler slowdown windows and
//!   transient invocation failures injected into the event loop,
//!   countered by deadlines with jittered-backoff retries, failover
//!   re-dispatch, admission control and degraded-mode fallback — all
//!   off by default, in which case the simulator is pinned
//!   bit-identical to the fault-free engine.

pub mod arrivals;
pub mod cli;
pub mod faults;
pub mod planner;

use std::cmp::Ordering;
use std::collections::VecDeque;

use crate::obs::{Breach, Recorder, StreamStats, TraceBuffer,
                 PID_FLEET, PID_OBS, PID_REQ};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::{percentile_sorted, percentile_with_failures};

use self::faults::{FaultPlan, ResilienceCfg};

// ------------------------------------------------------------------------
// Profiles: what the simulator charges per request
// ------------------------------------------------------------------------

/// Per (model, device) serving numbers — a lean projection of
/// [`crate::sim::DesignLatencyProfile`] (which carries names and
/// provenance; the inner loop only needs the two latencies).
#[derive(Debug, Clone, Copy)]
pub struct ServiceProfile {
    /// Per-clip service latency (ms) of the optimised design.
    pub service_ms: f64,
    /// Cost (ms) of loading this design onto a board that currently
    /// holds a different one.
    pub reconfig_ms: f64,
    /// Pipeline-fill share of `service_ms` (ms): the one-off
    /// line-buffer priming a batched invocation sequence pays once for
    /// the whole batch instead of once per clip (see
    /// `sim::DesignLatencyProfile::fill_ms`). 0 disables amortisation.
    pub fill_ms: f64,
}

impl ServiceProfile {
    /// Service time (ms) of one invocation sequence carrying `clips`
    /// clips of this design: the first clip pays the full per-clip
    /// latency, every further clip only the fill-free marginal cost.
    /// Exactly `service_ms` for `clips <= 1`, so batch-unaware callers
    /// and `max_batch = 1` fleets are bit-identical to the unbatched
    /// model.
    pub fn batch_ms(&self, clips: usize) -> f64 {
        if clips <= 1 {
            return self.service_ms;
        }
        // Clamp hand-built profiles where fill exceeds service; the
        // simulator-derived profiles satisfy fill < service.
        let marginal = (self.service_ms - self.fill_ms).max(0.0);
        self.service_ms + (clips - 1) as f64 * marginal
    }
}

/// The model × device profile grid the simulator and planner consume.
/// `None` marks an infeasible design point (model does not fit the
/// device); `costs[d]` is the relative board cost of device `d`.
#[derive(Debug, Clone)]
pub struct ProfileMatrix {
    pub models: Vec<String>,
    pub devices: Vec<String>,
    /// Relative board cost per device (see [`planner::board_cost`]).
    pub costs: Vec<f64>,
    grid: Vec<Vec<Option<ServiceProfile>>>,
}

impl ProfileMatrix {
    /// Empty grid (all points infeasible, unit costs).
    pub fn new(models: Vec<String>, devices: Vec<String>)
        -> ProfileMatrix {
        let grid = vec![vec![None; devices.len()]; models.len()];
        let costs = vec![1.0; devices.len()];
        ProfileMatrix { models, devices, costs, grid }
    }

    pub fn set(&mut self, model: usize, device: usize, p: ServiceProfile) {
        self.grid[model][device] = Some(p);
    }

    pub fn get(&self, model: usize, device: usize)
        -> Option<ServiceProfile> {
        self.grid[model][device]
    }

    pub fn model_index(&self, name: &str) -> Option<usize> {
        self.models.iter().position(|m| m == name)
    }

    pub fn device_index(&self, name: &str) -> Option<usize> {
        self.devices.iter().position(|d| d == name)
    }
}

// ------------------------------------------------------------------------
// Requests, boards, policies
// ------------------------------------------------------------------------

/// One inference request: a clip of `model` arriving at `arrival_ms`.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub id: usize,
    /// Row into the [`ProfileMatrix`].
    pub model: usize,
    pub arrival_ms: f64,
}

/// One board of the fleet: a device instance with an initially loaded
/// design (set by the planner / CLI, so a warm fleet pays no switch on
/// its first matching request).
#[derive(Debug, Clone, Copy)]
pub struct BoardSpec {
    /// Column into the [`ProfileMatrix`].
    pub device: usize,
    /// Initially loaded design (model row).
    pub preload: usize,
}

/// Which board a new arrival is queued on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Arrival `i` goes to board `i mod fleet size`.
    RoundRobin,
    /// Fewest requests queued + in service; ties to the lowest index.
    LeastLoaded,
    /// Earliest estimated completion, accounting for the board's
    /// backlog and the design-switch cost a mismatched board would
    /// pay — the policy that keeps designs resident where possible.
    SloAware,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "rr" | "round-robin" => Some(Policy::RoundRobin),
            "ll" | "least-loaded" => Some(Policy::LeastLoaded),
            "slo" | "slo-aware" => Some(Policy::SloAware),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLoaded => "least-loaded",
            Policy::SloAware => "slo-aware",
        }
    }
}

/// Per-board queue discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueDiscipline {
    /// Arrival order.
    Fifo,
    /// Cheapest work first (shortest service + switch on this board;
    /// ties to the earlier arrival) — trades a long clip's tail for
    /// the short clips' percentiles.
    Priority,
}

impl QueueDiscipline {
    pub fn parse(s: &str) -> Option<QueueDiscipline> {
        match s {
            "fifo" => Some(QueueDiscipline::Fifo),
            "priority" | "sjf" => Some(QueueDiscipline::Priority),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            QueueDiscipline::Fifo => "fifo",
            QueueDiscipline::Priority => "priority",
        }
    }
}

/// Clip-batching policy: how many clips one invocation sequence may
/// carry and how long an idle board holds the head clip waiting for
/// batchmates.
#[derive(Debug, Clone, Copy)]
pub struct BatchCfg {
    /// Largest batch (clips per invocation sequence). 1 disables
    /// batching — the simulator is then bit-identical to the
    /// unbatched model.
    pub max_batch: usize,
    /// Longest hold (ms) an *idle* board waits for the candidate batch
    /// to fill before starting short. 0 means purely opportunistic
    /// batching: only clips already queued when service starts are
    /// grouped, and no hold events exist.
    pub max_wait_ms: f64,
}

impl BatchCfg {
    pub fn new(max_batch: usize, max_wait_ms: f64) -> BatchCfg {
        BatchCfg { max_batch: max_batch.max(1), max_wait_ms }
    }

    /// Whether holds can occur (batch > 1 and a positive window).
    fn holds(&self) -> bool {
        self.max_batch > 1 && self.max_wait_ms > 0.0
    }
}

impl Default for BatchCfg {
    /// Batching off: one clip per invocation sequence, no hold.
    fn default() -> Self {
        BatchCfg { max_batch: 1, max_wait_ms: 0.0 }
    }
}

/// Fleet composition + serving policy for one simulation run.
#[derive(Debug, Clone)]
pub struct FleetCfg {
    pub boards: Vec<BoardSpec>,
    pub policy: Policy,
    pub queue: QueueDiscipline,
    /// The latency objective (ms); violations are counted per request.
    pub slo_ms: f64,
    /// Clip batching (default: off).
    pub batch: BatchCfg,
    /// Injected faults (default: none — bit-identical to the
    /// fault-free simulator).
    pub faults: FaultPlan,
    /// Resilience policies (default: all off).
    pub resilience: ResilienceCfg,
}

// ------------------------------------------------------------------------
// Metrics
// ------------------------------------------------------------------------

/// Per-board outcome of a simulation run.
#[derive(Debug, Clone)]
pub struct BoardReport {
    pub device: usize,
    pub completed: usize,
    /// Invocation sequences started (== completed when batching off).
    pub batches: usize,
    pub switches: usize,
    pub busy_ms: f64,
    /// busy time / makespan.
    pub utilization: f64,
}

/// Fleet-level outcome of a simulation run. All fields are
/// deterministic functions of (profiles, cfg, arrivals) — no wall
/// clock anywhere — so a fixed seed reproduces them bit-for-bit.
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    pub completed: usize,
    /// Requests no board could serve (their model fits no board's
    /// device) — always 0 for planner-built fleets.
    pub dropped: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// Completed requests per second of simulated time.
    pub throughput_rps: f64,
    /// Last completion time (simulated ms; arrivals start near 0).
    pub makespan_ms: f64,
    pub slo_ms: f64,
    pub slo_violations: usize,
    pub switches: usize,
    /// Invocation sequences started across the fleet. Equals
    /// `completed` when batching is off; under batching,
    /// `completed / batches` is the realised mean batch size.
    pub batches: usize,
    /// Simulator events processed (arrivals + completions + expired
    /// batch holds; under faults also crashes, recoveries and
    /// retries) — the bench's events/sec numerator.
    pub events: usize,
    /// Arrivals rejected by admission control (never queued).
    pub shed: usize,
    /// Queued attempts that blew their per-attempt deadline.
    pub timeouts: usize,
    /// Retry attempts scheduled (timeouts, transient failures and
    /// stranded failovers that found no live board).
    pub retries: usize,
    /// Clips re-dispatched off a crashed board (queued or in flight).
    pub failovers: usize,
    /// Requests downgraded to their degraded-mode fallback model.
    pub fallbacks: usize,
    /// Requests lost for good: out of retry budget after a timeout,
    /// transient failure or crash. Always 0 without faults/policies.
    pub failed: usize,
    /// Goodput tail latency: p99 over admitted requests, counting
    /// each failed request as `+inf`. Bit-identical to `p99_ms` when
    /// nothing failed, `+inf` when the tail is dominated by losses.
    pub goodput_p99_ms: f64,
    /// SLO burn-rate monitor firings from the streaming telemetry
    /// pipeline ([`crate::obs::StreamStats`]) — the future
    /// autoscaler's trigger signal. Always empty when no stats
    /// pipeline is attached (the default), so the tracing-off
    /// bit-identity pins are unaffected.
    pub breaches: Vec<Breach>,
    pub boards: Vec<BoardReport>,
}

impl FleetMetrics {
    pub fn mean_utilization(&self) -> f64 {
        if self.boards.is_empty() {
            return 0.0;
        }
        self.boards.iter().map(|b| b.utilization).sum::<f64>()
            / self.boards.len() as f64
    }

    pub fn slo_met(&self) -> bool {
        self.p99_ms <= self.slo_ms
    }

    /// Requests admitted into the fleet that ran to a terminal state
    /// (completed or failed) — the goodput-p99 population.
    pub fn admitted(&self) -> usize {
        self.completed + self.failed
    }

    /// Any fault-injection or resilience activity in this run (used
    /// by reports to decide whether the resilience block is worth
    /// printing).
    pub fn resilience_touched(&self) -> bool {
        self.shed + self.timeouts + self.retries + self.failovers
            + self.fallbacks + self.failed > 0
    }

    /// Realised mean clips per invocation sequence (1.0 for an empty
    /// run, so reports divide safely).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            1.0
        } else {
            self.completed as f64 / self.batches as f64
        }
    }
}

// ------------------------------------------------------------------------
// Event-driven simulator
// ------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum EventKind {
    /// Index into the arrivals slice.
    Arrival(usize),
    /// Board `.0` finished the invocation sequence it started in
    /// service epoch `.1` (stale epochs — the board crashed mid
    /// sequence — are ignored).
    Done(usize, u64),
    /// A batch hold expired on board `.0`; `.1` is the hold epoch the
    /// event was armed for (stale epochs are ignored — the board
    /// started or re-held in the meantime).
    HoldExpired(usize, u64),
    /// Board `.0` crashes: queue and in-flight work fail over.
    Crash(usize),
    /// Board `.0` comes back up, cold (no design loaded).
    Recover(usize),
    /// Request `.0` (arrival index) retries after its backoff.
    Retry(usize),
}

/// Simulator event. The `Ord` impl is the pop contract — earliest
/// `(t_ms, seq)` first (reversed for max-heap semantics): equal times
/// break by insertion sequence, which makes the event order — and
/// therefore the whole run — independent of float coincidences and
/// fully deterministic. The hot loop runs on [`CalendarQueue`], which
/// pops in exactly this order; the impls are kept as the reference
/// ordering for the heap-equivalence test.
#[derive(Debug, Clone, Copy)]
struct Event {
    t_ms: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for Event {
    fn cmp(&self, o: &Self) -> Ordering {
        // Reversed: the max-heap pops the minimum (time, seq).
        o.t_ms.total_cmp(&self.t_ms).then_with(|| o.seq.cmp(&self.seq))
    }
}

/// Order-preserving bit mapping of an `f64`: `key_bits(a) < key_bits(b)`
/// iff `a.total_cmp(&b) == Less`. Sign-magnitude floats become
/// monotone unsigned integers by flipping the sign bit for positives
/// and all bits for negatives — the calendar queue compares these
/// instead of calling `total_cmp` per element.
fn key_bits(t: f64) -> u64 {
    let b = t.to_bits();
    if b >> 63 == 1 { !b } else { b | (1 << 63) }
}

/// Calendar (bucket) event queue — the simulator's hot loop structure.
///
/// Events hash into `buckets.len()` (a power of two) time buckets of
/// `width_ms` each, wrapping around like days on a wall calendar:
/// bucket `tick & mask` holds every pending event whose time falls in
/// tick `tick = t_ms / width_ms` (plus aliases from other "laps",
/// filtered on pop). Because discrete-event time is monotone — every
/// push is at or after the last popped time — the pop cursor only
/// moves forward, and popping is an O(bucket occupancy) scan of the
/// current tick instead of the binary heap's O(log n) sift. Width is
/// sized to the mean arrival gap, so the common case is a handful of
/// events per tick.
///
/// Pop order is **exactly** the reference `BinaryHeap<Event>` order —
/// minimum `(t_ms, seq)` by `total_cmp`, ties by insertion sequence —
/// which is what keeps the engine bit-identical to the heap simulator
/// (pinned by the equivalence test and every golden/obs byte pin).
struct CalendarQueue {
    buckets: Vec<Vec<Event>>,
    /// Bucket time width (simulated ms).
    width: f64,
    /// `buckets.len() - 1`; the length is a power of two.
    mask: usize,
    /// Pending events across all buckets.
    len: usize,
    /// The tick the next pop starts scanning from. Monotone
    /// non-decreasing (DES time never goes backwards).
    cursor: u64,
}

impl CalendarQueue {
    /// Size for a run of `n_hint` root events spanning `span_ms`:
    /// bucket width ≈ the mean event gap (one arrival per tick on
    /// average), bucket count the next power of two that keeps
    /// occupancy low. Degenerate spans (empty runs, all-at-zero
    /// bursts) fall back to a 1 ms width — correctness never depends
    /// on the sizing, only the constant factor does.
    fn for_horizon(n_hint: usize, span_ms: f64) -> CalendarQueue {
        let n_buckets = n_hint.clamp(16, 1 << 20).next_power_of_two();
        let width = span_ms / n_hint.max(1) as f64;
        let width = if width.is_finite() && width > 0.0 {
            width
        } else {
            1.0
        };
        CalendarQueue {
            buckets: (0..n_buckets).map(|_| Vec::new()).collect(),
            width,
            mask: n_buckets - 1,
            len: 0,
            cursor: 0,
        }
    }

    /// `t / width` as a saturating integer tick (the `as` cast clamps
    /// negatives to 0 and huge values to `u64::MAX`, so hostile floats
    /// only cost scan time, never unsoundness).
    fn tick(&self, t_ms: f64) -> u64 {
        (t_ms / self.width) as u64
    }

    fn push(&mut self, ev: Event) {
        if self.len >= self.buckets.len() * 4 {
            self.grow();
        }
        let bi = (self.tick(ev.t_ms) as usize) & self.mask;
        self.buckets[bi].push(ev);
        self.len += 1;
    }

    /// Double the bucket count (same width, so existing ticks — and
    /// the cursor — stay valid) and rehash. Amortised O(1) per push,
    /// exactly like `Vec` growth.
    fn grow(&mut self) {
        let n = self.buckets.len() * 2;
        let mut pending: Vec<Event> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            pending.append(b);
        }
        self.buckets = (0..n).map(|_| Vec::new()).collect();
        self.mask = n - 1;
        for ev in pending {
            let bi = (self.tick(ev.t_ms) as usize) & self.mask;
            self.buckets[bi].push(ev);
        }
    }

    /// Remove and return the minimum `(t_ms, seq)` event. Scans ticks
    /// forward from the cursor; the earliest non-empty tick contains
    /// the global minimum because time is monotone. If a whole lap of
    /// the calendar holds nothing (a sparse far-future schedule, e.g.
    /// a lone recovery event), falls back to one O(len) global scan
    /// and jumps the cursor there.
    fn pop(&mut self) -> Option<Event> {
        if self.len == 0 {
            return None;
        }
        for step in 0..self.buckets.len() as u64 {
            let k = self.cursor.wrapping_add(step);
            let bi = (k as usize) & self.mask;
            let mut best: Option<(u64, u64, usize)> = None;
            for (i, e) in self.buckets[bi].iter().enumerate() {
                if (e.t_ms / self.width) as u64 != k {
                    continue; // an alias from another lap
                }
                let key = (key_bits(e.t_ms), e.seq);
                let better = match best {
                    None => true,
                    Some((kb, sb, _)) => key < (kb, sb),
                };
                if better {
                    best = Some((key.0, key.1, i));
                }
            }
            if let Some((_, _, i)) = best {
                self.cursor = k;
                self.len -= 1;
                return Some(self.buckets[bi].swap_remove(i));
            }
        }
        self.pop_sparse()
    }

    /// The slow path of [`CalendarQueue::pop`]: every pending event is
    /// more than one calendar lap ahead of the cursor.
    fn pop_sparse(&mut self) -> Option<Event> {
        let mut loc: Option<(usize, usize)> = None;
        let mut best = (u64::MAX, u64::MAX);
        for (bi, bucket) in self.buckets.iter().enumerate() {
            for (i, e) in bucket.iter().enumerate() {
                let key = (key_bits(e.t_ms), e.seq);
                if loc.is_none() || key < best {
                    best = key;
                    loc = Some((bi, i));
                }
            }
        }
        let (bi, i) = loc?;
        self.len -= 1;
        let ev = self.buckets[bi].swap_remove(i);
        self.cursor = self.tick(ev.t_ms);
        Some(ev)
    }
}

/// Sentinel "no design loaded" row for a board that crashed (it comes
/// back cold and pays a full reconfiguration on its first sequence).
/// Never a valid model row, so every `prev == model` check misses.
const NOTHING: usize = usize::MAX;

/// Live board state during a run, as index-based struct-of-arrays:
/// board `b`'s state is element `b` of every vector (mirroring the
/// PR-1 zero-clone SA layout). The dispatch policies scan a handful of
/// hot fields (`up`, `free_at_ms`, `backlog_ms`, `tail_model`, queue
/// lengths) across the whole fleet on **every arrival** — packing each
/// field contiguously keeps those scans on a few cache lines instead
/// of striding through 200-byte board structs.
struct Boards {
    device: Vec<usize>,
    /// Currently loaded design (model row), or [`NOTHING`] after a
    /// crash wiped the configuration.
    loaded: Vec<usize>,
    /// Design loaded once the whole queue has drained — the backlog
    /// estimator's switch-cost anchor.
    tail_model: Vec<usize>,
    queue: Vec<VecDeque<Request>>,
    /// Clips of the in-flight invocation sequence (empty = idle).
    /// Taken with `mem::take` and restored (cleared, capacity kept)
    /// by the handlers, so steady-state batches allocate nothing.
    in_service: Vec<Vec<Request>>,
    free_at_ms: Vec<f64>,
    /// Estimated queued work (service + expected switches), ms.
    backlog_ms: Vec<f64>,
    busy_ms: Vec<f64>,
    completed: Vec<usize>,
    switches: Vec<usize>,
    batches: Vec<usize>,
    /// An idle board waiting out a batch hold window.
    holding: Vec<bool>,
    /// Bumped every time a hold is armed; a `HoldExpired` event only
    /// acts when its epoch still matches (invalidates stale timers).
    hold_epoch: Vec<u64>,
    /// False while crashed: the board takes no dispatches and its
    /// pending `Done` is stale.
    up: Vec<bool>,
    /// Bumped when a crash interrupts an in-flight sequence, so the
    /// sequence's already-scheduled `Done` no-ops. 0 forever in a
    /// fault-free run, where every `Done` therefore matches.
    service_epoch: Vec<u64>,
    /// The in-flight sequence drew a transient failure: its `Done`
    /// retries the clips instead of completing them.
    service_failed: Vec<bool>,
    /// Trace-only (written when a recorder is attached, read at the
    /// matching `Done`): start time and switch/fill share of the
    /// in-flight sequence, for the reconfig/fill/service slice
    /// decomposition on the board's Perfetto track.
    seq_start_ms: Vec<f64>,
    seq_reconfig_ms: Vec<f64>,
    seq_fill_ms: Vec<f64>,
}

impl Boards {
    fn new(specs: &[BoardSpec]) -> Boards {
        let n = specs.len();
        Boards {
            device: specs.iter().map(|b| b.device).collect(),
            loaded: specs.iter().map(|b| b.preload).collect(),
            tail_model: specs.iter().map(|b| b.preload).collect(),
            queue: (0..n).map(|_| VecDeque::new()).collect(),
            in_service: (0..n).map(|_| Vec::new()).collect(),
            free_at_ms: vec![0.0; n],
            backlog_ms: vec![0.0; n],
            busy_ms: vec![0.0; n],
            completed: vec![0; n],
            switches: vec![0; n],
            batches: vec![0; n],
            holding: vec![false; n],
            hold_epoch: vec![0; n],
            up: vec![true; n],
            service_epoch: vec![0; n],
            service_failed: vec![false; n],
            seq_start_ms: vec![0.0; n],
            seq_reconfig_ms: vec![0.0; n],
            seq_fill_ms: vec![0.0; n],
        }
    }

    fn len(&self) -> usize {
        self.device.len()
    }

    /// Estimated cost of serving one clip of `model` right after
    /// `prev` on board `b`. Batch-aware: when batching is on and the
    /// clip joins the same design's **unfilled** tail batch, it rides
    /// that invocation sequence and pays only the fill-free marginal
    /// cost. The tail run is counted, not assumed: `tail % max_batch`
    /// clips sit in the partially-built last batch, so a zero
    /// remainder (empty tail, or a tail at exactly the cap) means the
    /// joining clip opens a *new* sequence and pays the full per-clip
    /// cost — the case the old estimator undercounted, systematically
    /// under-pricing saturated boards. A mismatched design pays full
    /// service plus the switch.
    fn cost_after(&self, profiles: &ProfileMatrix, b: usize,
                  prev: usize, model: usize, batch: &BatchCfg)
        -> Option<f64> {
        let p = profiles.get(model, self.device[b])?;
        if prev == model {
            if batch.max_batch > 1 {
                let tail = self.queue[b]
                    .iter()
                    .rev()
                    .take_while(|r| r.model == model)
                    .count();
                if tail % batch.max_batch != 0 {
                    return Some(p.batch_ms(2) - p.batch_ms(1));
                }
                return Some(p.batch_ms(1));
            }
            return Some(p.service_ms);
        }
        Some(p.service_ms + p.reconfig_ms)
    }
}

/// The running simulation: all mutable run state in one place so the
/// fault and resilience handlers (crash failover, retries, admission
/// control) can reach the heap, the boards and the counters without
/// threading a dozen arguments through every call.
struct Sim<'a> {
    profiles: &'a ProfileMatrix,
    cfg: &'a FleetCfg,
    arrivals: &'a [Request],
    boards: Boards,
    events_q: CalendarQueue,
    seq: u64,
    /// Per-request resilience side state, indexed by arrival position
    /// (SoA): the current model row (degraded-mode fallback may
    /// downgrade it), the remaining retry budget, and when the current
    /// attempt was queued — the per-attempt deadline's anchor.
    req_model: Vec<usize>,
    req_attempts_left: Vec<usize>,
    req_enqueued_ms: Vec<f64>,
    /// Reused crash-failover scratch (drained after every crash), so
    /// failover re-dispatch allocates nothing in steady state.
    failover_buf: Vec<Request>,
    latencies: Vec<f64>,
    dropped: usize,
    shed: usize,
    timeouts: usize,
    retries: usize,
    failovers: usize,
    fallbacks: usize,
    failed: usize,
    events: usize,
    rr_next: usize,
    makespan_ms: f64,
    /// Transient-failure draws ([`faults::STREAM_FLAKY`]); only ever
    /// advanced when `flaky_fail_prob > 0`.
    flaky_rng: Rng,
    /// Backoff jitter draws ([`faults::STREAM_BACKOFF`]); only ever
    /// advanced when a retry is scheduled.
    backoff_rng: Rng,
    /// Observability sink (obs subsystem). `None` — the default — is
    /// the production hot path: every recording site is a single
    /// `is-None` branch with no allocation, and recorded timestamps
    /// are simulated milliseconds, so attaching a recorder changes no
    /// metric bit (pinned by `rust/tests/obs.rs`).
    rec: Option<&'a mut TraceBuffer>,
    /// Streaming telemetry pipeline (windowed sketches + burn-rate
    /// monitors). Same zero-cost discipline as `rec`: `None` — the
    /// default — leaves every hot-loop site a single `is-None` branch
    /// and the metrics bit-identical.
    stats: Option<&'a mut StreamStats>,
}

/// Run the fleet through a sorted arrival stream. Panics if `arrivals`
/// is not sorted by `arrival_ms` (the arrival constructors guarantee
/// it) or the fleet is empty. With `cfg.faults` empty and
/// `cfg.resilience` all off (the defaults) the run is bit-identical
/// to the fault-free simulator: no fault events are scheduled, no
/// fault RNG stream is drawn, and no float operation changes.
pub fn simulate_fleet(profiles: &ProfileMatrix, cfg: &FleetCfg,
                      arrivals: &[Request]) -> FleetMetrics {
    simulate_fleet_traced(profiles, cfg, arrivals, None)
}

/// [`simulate_fleet`] with an optional trace recorder attached: board
/// service timelines (reconfig/fill/service slices), request
/// lifecycle flows (arrival → enqueue → complete | shed | dropped |
/// failed), live counters (queue depth, boards up/busy, retries,
/// shed) and end-of-run gauges land in `rec`. Metrics are
/// bit-identical with and without a recorder; the trace itself is
/// byte-reproducible per seed (timestamps are simulated time — no
/// wall clock anywhere).
pub fn simulate_fleet_traced(profiles: &ProfileMatrix, cfg: &FleetCfg,
                             arrivals: &[Request],
                             rec: Option<&mut TraceBuffer>)
    -> FleetMetrics {
    simulate_fleet_obs(profiles, cfg, arrivals, rec, None)
}

/// [`simulate_fleet_traced`] with an optional streaming-stats pipeline
/// attached: [`StreamStats`] hooks fire inside the event loop
/// (windows advance on simulated time, latencies stream into the
/// sharded quantile sketches, burn-rate monitors evaluate at window
/// closes), closed windows mirror into the recorder's timestamped
/// gauge series, and breaches land both in `FleetMetrics::breaches`
/// and as `obs` instants on pid 5 of the trace. Metrics are
/// bit-identical with and without either sink; the stats series is a
/// pure function of (profiles, cfg, arrivals) — only the
/// self-profiling fields (`engine_events`, `engine_wall_s`) touch the
/// wall clock, and they never enter the exported series.
pub fn simulate_fleet_obs(profiles: &ProfileMatrix, cfg: &FleetCfg,
                          arrivals: &[Request],
                          mut rec: Option<&mut TraceBuffer>,
                          stats: Option<&mut StreamStats>)
    -> FleetMetrics {
    assert!(!cfg.boards.is_empty(), "fleet has no boards");
    debug_assert!(arrivals.windows(2)
                      .all(|w| w[0].arrival_ms <= w[1].arrival_ms),
                  "arrivals must be time-sorted");

    let boards = Boards::new(&cfg.boards);

    if let Some(r) = rec.as_deref_mut() {
        r.process(PID_FLEET, "fleet boards");
        for (i, b) in cfg.boards.iter().enumerate() {
            r.track(PID_FLEET, i as u64,
                    &format!("board{} {}", i,
                             profiles.devices[b.device]));
        }
        r.process(PID_REQ, "requests");
        r.track(PID_REQ, 0, "lifecycle");
    }

    // Calendar sized to the arrival stream: one tick ≈ one mean
    // arrival gap (empty and single-burst streams fall back to 1 ms).
    let span_ms = arrivals.last().map(|r| r.arrival_ms).unwrap_or(0.0);
    let mut sim = Sim {
        profiles,
        cfg,
        arrivals,
        boards,
        events_q: CalendarQueue::for_horizon(arrivals.len(), span_ms),
        seq: 0,
        req_model: arrivals.iter().map(|r| r.model).collect(),
        req_attempts_left: vec![cfg.resilience.retries;
                                arrivals.len()],
        req_enqueued_ms: vec![0.0; arrivals.len()],
        failover_buf: Vec::new(),
        latencies: Vec::with_capacity(arrivals.len()),
        dropped: 0,
        shed: 0,
        timeouts: 0,
        retries: 0,
        failovers: 0,
        fallbacks: 0,
        failed: 0,
        events: 0,
        rr_next: 0,
        makespan_ms: 0.0,
        flaky_rng: Rng::stream(cfg.faults.seed, faults::STREAM_FLAKY),
        backoff_rng: Rng::stream(cfg.resilience.seed,
                                 faults::STREAM_BACKOFF),
        rec,
        stats,
    };
    if let Some(s) = sim.stats.as_deref_mut() {
        s.set_boards_up(cfg.boards.len() as u64);
    }
    for (i, r) in arrivals.iter().enumerate() {
        sim.push(r.arrival_ms, EventKind::Arrival(i));
    }
    // Fault events ride the same deterministic heap; an empty plan
    // pushes nothing, keeping the event sequence byte-for-byte what
    // the fault-free engine produced.
    for c in &cfg.faults.crashes {
        if c.board < cfg.boards.len() {
            sim.push(c.at_ms, EventKind::Crash(c.board));
            if c.recover_ms.is_finite() {
                sim.push(c.recover_ms, EventKind::Recover(c.board));
            }
        }
    }
    // Self-profiling only when a stats pipeline is attached: the
    // tracing-off hot path never reads the wall clock.
    let timer = sim.stats.is_some().then(std::time::Instant::now);
    sim.run();
    let newly = match sim.stats.as_deref_mut() {
        Some(s) => s.finalize(),
        None => 0,
    };
    if newly > 0 {
        sim.window_gauges(newly);
    }
    if let Some(t) = timer {
        let events = sim.events as u64;
        if let Some(s) = sim.stats.as_deref_mut() {
            s.engine_events = events;
            s.engine_wall_s = t.elapsed().as_secs_f64();
        }
    }

    let slo_violations =
        sim.latencies.iter().filter(|&&l| l > cfg.slo_ms).count();
    let mean_ms = crate::util::stats::mean(&sim.latencies);
    // One sort serves every percentile and the max (metrics are on the
    // benched path — events/sec should measure the simulator, not
    // repeated bookkeeping sorts).
    let mut sorted = sim.latencies;
    sorted.sort_by(|a, b| a.total_cmp(b));
    let makespan_ms = sim.makespan_ms;
    let board_reports: Vec<BoardReport> = (0..sim.boards.len())
        .map(|b| BoardReport {
            device: sim.boards.device[b],
            completed: sim.boards.completed[b],
            batches: sim.boards.batches[b],
            switches: sim.boards.switches[b],
            busy_ms: sim.boards.busy_ms[b],
            utilization: if makespan_ms > 0.0 {
                sim.boards.busy_ms[b] / makespan_ms
            } else {
                0.0
            },
        })
        .collect();
    let metrics = FleetMetrics {
        completed: sorted.len(),
        dropped: sim.dropped,
        p50_ms: percentile_sorted(&sorted, 50.0),
        p95_ms: percentile_sorted(&sorted, 95.0),
        p99_ms: percentile_sorted(&sorted, 99.0),
        mean_ms,
        max_ms: sorted.last().copied().unwrap_or(0.0),
        throughput_rps: if makespan_ms > 0.0 {
            sorted.len() as f64 / (makespan_ms / 1e3)
        } else {
            0.0
        },
        makespan_ms,
        slo_ms: cfg.slo_ms,
        slo_violations,
        switches: sim.boards.switches.iter().sum(),
        batches: sim.boards.batches.iter().sum(),
        events: sim.events,
        shed: sim.shed,
        timeouts: sim.timeouts,
        retries: sim.retries,
        failovers: sim.failovers,
        fallbacks: sim.fallbacks,
        failed: sim.failed,
        goodput_p99_ms: percentile_with_failures(&sorted, sim.failed,
                                                 99.0),
        breaches: sim.stats.as_deref()
            .map(|s| s.breaches().to_vec())
            .unwrap_or_default(),
        boards: board_reports,
    };
    if !metrics.breaches.is_empty() {
        if let Some(r) = sim.rec.as_deref_mut() {
            r.process(PID_OBS, "slo monitors");
            r.track(PID_OBS, 0, "burn rate");
            for b in &metrics.breaches {
                r.instant(PID_OBS, 0, "obs",
                          &format!("breach:{}", b.monitor.name()),
                          b.at_ms * 1000.0, vec![
                    ("burn_rate", Json::Num(b.burn_rate)),
                    ("threshold", Json::Num(b.threshold)),
                    ("window", Json::Num(b.window as f64)),
                ]);
            }
        }
    }
    if let Some(r) = sim.rec {
        r.gauge("fleet/batches", metrics.batches as f64);
        r.gauge("fleet/completed", metrics.completed as f64);
        r.gauge("fleet/dropped", metrics.dropped as f64);
        r.gauge("fleet/events", metrics.events as f64);
        r.gauge("fleet/failed", metrics.failed as f64);
        r.gauge("fleet/failovers", metrics.failovers as f64);
        r.gauge("fleet/makespan_ms", metrics.makespan_ms);
        r.gauge("fleet/p50_ms", metrics.p50_ms);
        r.gauge("fleet/p95_ms", metrics.p95_ms);
        r.gauge("fleet/p99_ms", metrics.p99_ms);
        r.gauge("fleet/retries", metrics.retries as f64);
        r.gauge("fleet/shed", metrics.shed as f64);
        r.gauge("fleet/switches", metrics.switches as f64);
        r.gauge("fleet/throughput_rps", metrics.throughput_rps);
        r.gauge("fleet/timeouts", metrics.timeouts as f64);
    }
    metrics
}

impl Sim<'_> {
    /// Schedule an event, assigning the next tie-break sequence.
    fn push(&mut self, t_ms: f64, kind: EventKind) {
        self.events_q.push(Event { t_ms, seq: self.seq, kind });
        self.seq += 1;
    }

    fn run(&mut self) {
        while let Some(ev) = self.events_q.pop() {
            self.events += 1;
            let now = ev.t_ms;
            // Close stats windows *before* processing the event: an
            // event exactly on a boundary belongs to the next window.
            let newly = match self.stats.as_deref_mut() {
                Some(s) => s.advance_to(now),
                None => 0,
            };
            if newly > 0 {
                self.window_gauges(newly);
            }
            match ev.kind {
                EventKind::Arrival(i) => self.on_arrival(i, now),
                EventKind::Done(b, epoch) => {
                    self.on_done(b, epoch, now)
                }
                EventKind::HoldExpired(b, epoch) => {
                    self.on_hold(b, epoch, now)
                }
                EventKind::Crash(b) => self.on_crash(b, now),
                EventKind::Recover(b) => self.on_recover(b, now),
                EventKind::Retry(i) => self.on_retry(i, now),
            }
        }
    }

    /// Mirror the latest `newly` closed stats windows into the
    /// recorder's timestamped gauge series, so `--metrics-out` gauges
    /// reflect the run's time-series (last-write-wins per window
    /// boundary) instead of only its end-of-run values. Distinct
    /// `fleet/window/*` names keep the exact end-of-run gauges
    /// untouched.
    fn window_gauges(&mut self, newly: usize) {
        let Some(s) = self.stats.as_deref() else { return };
        let Some(r) = self.rec.as_deref_mut() else { return };
        let rows = s.rows();
        for row in &rows[rows.len() - newly..] {
            let ts = row.end_ms;
            r.gauge_at("fleet/window/boards_up", ts,
                       row.boards_up as f64);
            r.gauge_at("fleet/window/completions", ts,
                       row.completions as f64);
            r.gauge_at("fleet/window/p99_ms", ts, row.p99_ms);
            r.gauge_at("fleet/window/queue_depth", ts,
                       row.queue_depth as f64);
            r.gauge_at("fleet/window/retries", ts, row.retries as f64);
            r.gauge_at("fleet/window/sheds", ts, row.sheds as f64);
        }
    }

    fn on_arrival(&mut self, i: usize, now: f64) {
        // Internally `id` is the arrival index so retries and
        // failovers can find the request's side state; the simulator
        // only ever reads `model` and `arrival_ms`, so normalising
        // the id leaves the fault-free run untouched.
        let mut req = Request {
            id: i,
            model: self.req_model[i],
            arrival_ms: self.arrivals[i].arrival_ms,
        };
        if let Some(r) = self.rec.as_deref_mut() {
            let ts = now * 1000.0;
            r.flow_start(PID_REQ, 0, "req", "req", ts, i as u64);
            r.instant(PID_REQ, 0, "req", "arrival", ts, vec![
                ("model", Json::Num(req.model as f64)),
                ("req", Json::Num(i as f64)),
            ]);
        }
        if let Some(s) = self.stats.as_deref_mut() {
            s.on_arrival();
        }
        if self.cfg.resilience.shed
            && self.cfg.resilience.deadline_ms > 0.0
        {
            let deadline = self.cfg.resilience.deadline_ms;
            let est = best_completion_est(self.profiles, &self.boards,
                                          req.model, now,
                                          &self.cfg.batch);
            let admits = matches!(est, Some(e) if e - now <= deadline);
            if !admits {
                // Saturated (or no live board): degrade to the
                // fallback variant if that one still fits the
                // deadline, else shed the request at the door.
                let fb = self
                    .cfg
                    .resilience
                    .fallback
                    .get(req.model)
                    .copied()
                    .flatten()
                    .filter(|&f| f != req.model)
                    .filter(|&f| {
                        matches!(
                            best_completion_est(self.profiles,
                                                &self.boards, f, now,
                                                &self.cfg.batch),
                            Some(e) if e - now <= deadline)
                    });
                match fb {
                    Some(f) => {
                        self.fallbacks += 1;
                        if let Some(r) = self.rec.as_deref_mut() {
                            r.instant(PID_REQ, 0, "req", "fallback",
                                      now * 1000.0, vec![
                                ("from", Json::Num(req.model as f64)),
                                ("req", Json::Num(i as f64)),
                                ("to", Json::Num(f as f64)),
                            ]);
                        }
                        self.req_model[i] = f;
                        req.model = f;
                    }
                    None => {
                        self.shed += 1;
                        if let Some(s) = self.stats.as_deref_mut() {
                            s.on_shed();
                        }
                        if let Some(r) = self.rec.as_deref_mut() {
                            let ts = now * 1000.0;
                            r.instant(PID_REQ, 0, "req", "shed", ts,
                                      vec![("req",
                                            Json::Num(i as f64))]);
                            r.flow_end(PID_REQ, 0, "req", "req", ts,
                                       i as u64);
                            let shed = self.shed;
                            r.counter(PID_REQ, 0, "shed", ts,
                                      shed as f64);
                        }
                        return;
                    }
                }
            }
        }
        if !self.try_enqueue(req, now) {
            // No capable live board right now. With a retry budget
            // the request backs off and tries again (the fleet may
            // just be mid-crash); without one it is dropped, exactly
            // as the fault-free engine drops unservable models.
            if self.req_attempts_left[i] > 0 {
                self.retry_or_fail(i, now);
            } else {
                self.dropped += 1;
                if let Some(r) = self.rec.as_deref_mut() {
                    let ts = now * 1000.0;
                    r.instant(PID_REQ, 0, "req", "dropped", ts,
                              vec![("req", Json::Num(i as f64))]);
                    r.flow_end(PID_REQ, 0, "req", "req", ts, i as u64);
                }
            }
        }
    }

    /// Dispatch `req` onto a board and queue it there, starting the
    /// board if idle. False when no live board can serve the model.
    //
    // The `expect` documents a dispatch invariant (the chosen board
    // is capable by construction); recovering would mean simulating
    // on corrupt state and reporting wrong metrics as real.
    #[allow(clippy::disallowed_methods)]
    fn try_enqueue(&mut self, req: Request, now: f64) -> bool {
        let Some(b) = dispatch(self.profiles, &self.boards,
                               self.cfg.policy, &mut self.rr_next,
                               &req, now, &self.cfg.batch)
        else {
            return false;
        };
        self.req_enqueued_ms[req.id] = now;
        let (rid, rmodel) = (req.id, req.model);
        let est = self
            .boards
            .cost_after(self.profiles, b, self.boards.tail_model[b],
                        req.model, &self.cfg.batch)
            .expect("dispatch returned a capable board");
        self.boards.backlog_ms[b] += est;
        self.boards.tail_model[b] = req.model;
        self.boards.queue[b].push_back(req);
        let idle = self.boards.in_service[b].is_empty();
        if self.rec.is_some() || self.stats.is_some() {
            let depth: usize =
                self.boards.queue.iter().map(|q| q.len()).sum();
            if let Some(s) = self.stats.as_deref_mut() {
                s.set_queue_depth(depth as u64);
            }
            if let Some(r) = self.rec.as_deref_mut() {
                let ts = now * 1000.0;
                r.instant(PID_REQ, 0, "req", "enqueue", ts, vec![
                    ("board", Json::Num(b as f64)),
                    ("model", Json::Num(rmodel as f64)),
                    ("req", Json::Num(rid as f64)),
                ]);
                r.flow_step(PID_REQ, 0, "req", "req", ts, rid as u64);
                r.counter(PID_REQ, 0, "queue_depth", ts, depth as f64);
            }
        }
        if idle {
            self.maybe_start(b, now);
        }
        true
    }

    fn on_done(&mut self, b: usize, epoch: u64, now: f64) {
        if self.boards.service_epoch[b] != epoch {
            // The board crashed mid-sequence; this work already
            // failed over.
            return;
        }
        let failed_seq =
            std::mem::take(&mut self.boards.service_failed[b]);
        // Taken, processed, then restored cleared — the board's batch
        // vector keeps its capacity across sequences, so the hot loop
        // never allocates per completion.
        let mut batch = std::mem::take(&mut self.boards.in_service[b]);
        assert!(!batch.is_empty(),
                "completion without in-service request");
        if self.rec.is_some() {
            // Decompose the finished sequence into its
            // reconfig/fill/service slices on the board track. Emitted
            // at completion (not start) so a crash never leaves
            // forward-dated timestamps behind it — the interrupted
            // sequence's `Done` is staled above and draws nothing.
            let (start, reconfig_d, fill_d) = (
                self.boards.seq_start_ms[b],
                self.boards.seq_reconfig_ms[b],
                self.boards.seq_fill_ms[b],
            );
            let model = batch[0].model;
            let n = batch.len();
            let outcome = if failed_seq { "failed" } else { "ok" };
            if let Some(r) = self.rec.as_deref_mut() {
                let tid = b as u64;
                let args = |name: &'static str| vec![
                    ("clips", Json::Num(n as f64)),
                    ("model", Json::Num(model as f64)),
                    ("outcome", Json::Str(name.to_string())),
                ];
                let mut at = start * 1000.0;
                if reconfig_d > 0.0 {
                    r.slice(PID_FLEET, tid, "board", "reconfig", at,
                            reconfig_d * 1000.0, args(outcome));
                    at += reconfig_d * 1000.0;
                }
                if fill_d > 0.0 {
                    r.slice(PID_FLEET, tid, "board", "fill", at,
                            fill_d * 1000.0, args(outcome));
                    at += fill_d * 1000.0;
                }
                r.slice(PID_FLEET, tid, "board", "service", at,
                        (now * 1000.0 - at).max(0.0), args(outcome));
            }
        }
        if failed_seq {
            // Transient invocation failure: the board time was spent,
            // the results are lost, and every clip retries or fails.
            for req in &batch {
                if let Some(r) = self.rec.as_deref_mut() {
                    r.instant(PID_REQ, 0, "req", "service_failed",
                              now * 1000.0,
                              vec![("req",
                                    Json::Num(req.id as f64))]);
                }
                self.retry_or_fail(req.id, now);
            }
        } else {
            self.boards.completed[b] += batch.len();
            for req in &batch {
                let lat = now - req.arrival_ms;
                self.latencies.push(lat);
                if let Some(s) = self.stats.as_deref_mut() {
                    s.on_complete(lat, lat <= self.cfg.slo_ms);
                }
                if let Some(r) = self.rec.as_deref_mut() {
                    let ts = now * 1000.0;
                    r.instant(PID_REQ, 0, "req", "complete", ts, vec![
                        ("latency_ms", Json::Num(lat)),
                        ("req", Json::Num(req.id as f64)),
                    ]);
                    r.flow_end(PID_FLEET, b as u64, "req", "req", ts,
                               req.id as u64);
                }
            }
            if self.rec.is_some() {
                let done = self.latencies.len();
                if let Some(r) = self.rec.as_deref_mut() {
                    r.counter(PID_REQ, 0, "completed", now * 1000.0,
                              done as f64);
                }
            }
            self.makespan_ms = self.makespan_ms.max(now);
        }
        // Hand the emptied batch vector back (capacity intact) before
        // the next sequence gathers into it.
        batch.clear();
        self.boards.in_service[b] = batch;
        if !self.boards.queue[b].is_empty() {
            self.maybe_start(b, now);
        }
    }

    fn on_hold(&mut self, b: usize, epoch: u64, now: f64) {
        if self.boards.holding[b] && self.boards.hold_epoch[b] == epoch
            && self.boards.in_service[b].is_empty()
            && !self.boards.queue[b].is_empty()
        {
            self.boards.holding[b] = false;
            self.start_next(b, now);
        }
    }

    fn on_crash(&mut self, b: usize, now: f64) {
        if !self.boards.up[b] {
            return; // overlapping crash windows
        }
        // Reused scratch (always left empty): crashes drain into the
        // same buffer run after run, no per-crash allocation.
        let mut lost = std::mem::take(&mut self.failover_buf);
        debug_assert!(lost.is_empty());
        self.boards.up[b] = false;
        self.boards.holding[b] = false;
        if !self.boards.in_service[b].is_empty() {
            // The unfinished remainder of the interrupted
            // sequence never ran: refund it and stale the
            // pending `Done` via the service epoch.
            self.boards.busy_ms[b] -=
                (self.boards.free_at_ms[b] - now).max(0.0);
            self.boards.service_epoch[b] += 1;
            self.boards.service_failed[b] = false;
            lost.append(&mut self.boards.in_service[b]);
        }
        lost.extend(self.boards.queue[b].drain(..));
        self.boards.backlog_ms[b] = 0.0;
        self.boards.loaded[b] = NOTHING;
        self.boards.tail_model[b] = NOTHING;
        if self.rec.is_some() || self.stats.is_some() {
            let up = self.boards.up.iter().filter(|&&u| u).count();
            if let Some(s) = self.stats.as_deref_mut() {
                s.set_boards_up(up as u64);
            }
            if let Some(r) = self.rec.as_deref_mut() {
                let ts = now * 1000.0;
                r.instant(PID_FLEET, b as u64, "board", "crash", ts,
                          vec![("lost",
                                Json::Num(lost.len() as f64))]);
                r.counter(PID_REQ, 0, "boards_up", ts, up as f64);
            }
        }
        // Failover re-dispatch is free (no retry budget consumed);
        // only a clip stranded with no live capable board burns a
        // retry — or fails, if it has none left.
        for req in lost.drain(..) {
            self.failovers += 1;
            if let Some(r) = self.rec.as_deref_mut() {
                r.instant(PID_REQ, 0, "req", "failover", now * 1000.0,
                          vec![("req", Json::Num(req.id as f64))]);
            }
            if !self.try_enqueue(req, now) {
                self.retry_or_fail(req.id, now);
            }
        }
        self.failover_buf = lost;
    }

    fn on_recover(&mut self, b: usize, now: f64) {
        // Back up, cold: `loaded` stays `NOTHING`, so the first
        // sequence pays a full reconfiguration. Work that failed over
        // stays where it went; new arrivals find the board again.
        self.boards.up[b] = true;
        if self.rec.is_some() || self.stats.is_some() {
            let up = self.boards.up.iter().filter(|&&u| u).count();
            if let Some(s) = self.stats.as_deref_mut() {
                s.set_boards_up(up as u64);
            }
            if let Some(r) = self.rec.as_deref_mut() {
                let ts = now * 1000.0;
                r.instant(PID_FLEET, b as u64, "board", "recover", ts,
                          Vec::new());
                r.counter(PID_REQ, 0, "boards_up", ts, up as f64);
            }
        }
    }

    fn on_retry(&mut self, i: usize, now: f64) {
        let req = Request {
            id: i,
            model: self.req_model[i],
            arrival_ms: self.arrivals[i].arrival_ms,
        };
        if !self.try_enqueue(req, now) {
            self.retry_or_fail(i, now);
        }
    }

    /// Burn one retry (scheduling the next attempt after a jittered
    /// exponential backoff) or, with the budget exhausted, count the
    /// request as permanently failed.
    fn retry_or_fail(&mut self, i: usize, now: f64) {
        if self.req_attempts_left[i] > 0 {
            self.req_attempts_left[i] -= 1;
            self.retries += 1;
            if let Some(s) = self.stats.as_deref_mut() {
                s.on_retry();
            }
            let attempt = self.cfg.resilience.retries
                - self.req_attempts_left[i];
            if let Some(r) = self.rec.as_deref_mut() {
                let ts = now * 1000.0;
                r.instant(PID_REQ, 0, "req", "retry", ts, vec![
                    ("attempt", Json::Num(attempt as f64)),
                    ("req", Json::Num(i as f64)),
                ]);
            }
            if self.rec.is_some() {
                let retries = self.retries;
                if let Some(r) = self.rec.as_deref_mut() {
                    r.counter(PID_REQ, 0, "retries", now * 1000.0,
                              retries as f64);
                }
            }
            let delay = self
                .cfg
                .resilience
                .backoff_delay(attempt, &mut self.backoff_rng);
            self.push(now + delay, EventKind::Retry(i));
        } else {
            self.failed += 1;
            if let Some(s) = self.stats.as_deref_mut() {
                s.on_failed();
            }
            if let Some(r) = self.rec.as_deref_mut() {
                let ts = now * 1000.0;
                r.instant(PID_REQ, 0, "req", "failed", ts,
                          vec![("req", Json::Num(i as f64))]);
                r.flow_end(PID_REQ, 0, "req", "req", ts, i as u64);
            }
        }
    }

    /// Expire queued attempts that blew their deadline before
    /// service. Each expired clip retries (downgrading to its
    /// degraded-mode fallback when one is configured — a timeout is
    /// the saturation signal) or fails. The backlog estimator keeps
    /// the expired clips' contribution until the queue next drains;
    /// it is advisory and self-corrects on empty.
    fn sweep_timeouts(&mut self, b: usize, now: f64) {
        let deadline = self.cfg.resilience.deadline_ms;
        if deadline <= 0.0 {
            return;
        }
        let mut qi = 0;
        while qi < self.boards.queue[b].len() {
            let req = self.boards.queue[b][qi];
            if now - self.req_enqueued_ms[req.id] <= deadline {
                qi += 1;
                continue;
            }
            let _ = self.boards.queue[b].remove(qi);
            self.timeouts += 1;
            if let Some(s) = self.stats.as_deref_mut() {
                s.on_timeout();
            }
            if let Some(r) = self.rec.as_deref_mut() {
                r.instant(PID_REQ, 0, "req", "timeout", now * 1000.0,
                          vec![("req", Json::Num(req.id as f64))]);
            }
            if let Some(fb) = self
                .cfg
                .resilience
                .fallback
                .get(req.model)
                .copied()
                .flatten()
            {
                if fb != req.model {
                    self.req_model[req.id] = fb;
                    self.fallbacks += 1;
                }
            }
            self.retry_or_fail(req.id, now);
        }
    }

    /// Start the board's next invocation sequence — or, when batching
    /// with a hold window is on and the candidate batch is still
    /// short, arm a hold timer and wait for batchmates. Requires a
    /// non-empty queue and an idle board.
    fn maybe_start(&mut self, b: usize, now: f64) {
        let full = !self.cfg.batch.holds()
            || candidate_batch_len(self.profiles, &self.boards, b,
                                   self.cfg.queue, &self.cfg.batch)
                >= self.cfg.batch.max_batch;
        if full {
            self.boards.holding[b] = false;
            self.start_next(b, now);
        } else if !self.boards.holding[b] {
            self.boards.holding[b] = true;
            self.boards.hold_epoch[b] += 1;
            let epoch = self.boards.hold_epoch[b];
            self.push(now + self.cfg.batch.max_wait_ms,
                      EventKind::HoldExpired(b, epoch));
        }
        // Already holding with a still-short batch: keep waiting; the
        // armed timer (or a filling arrival) will start the sequence.
    }

    /// Pop the next invocation sequence off board `b`'s queue — the
    /// discipline's pick plus (under batching) every queued clip of
    /// the same model up to `max_batch`, in arrival order — and put
    /// it in service at time `now`, scheduling its completion event.
    /// Expired clips are timed out first; if that empties the queue
    /// the board simply stays idle.
    //
    // The `expect`s document queue invariants that hold by
    // construction (the pick index is in range, a queued request is
    // servable on its board); see `try_enqueue`.
    #[allow(clippy::disallowed_methods)]
    fn start_next(&mut self, b: usize, now: f64) {
        self.sweep_timeouts(b, now);
        if self.boards.queue[b].is_empty() {
            self.boards.holding[b] = false;
            self.boards.backlog_ms[b] = 0.0;
            self.boards.tail_model[b] = self.boards.loaded[b];
            return;
        }
        let pick = pick_index(self.profiles, &self.boards, b,
                              self.cfg.queue, &self.cfg.batch);
        let first = self
            .boards
            .queue[b]
            .remove(pick)
            .expect("queue checked non-empty");
        let model = first.model;
        // Gather the batch into the board's reused (empty, capacity
        // kept) in-service vector: one forward pass that keeps
        // non-matching clips compacted in arrival order — replacing
        // the old O(queue · batch) repeated `VecDeque::remove` scan.
        // Selected clips and survivors both keep arrival order, so
        // the gathered batch is identical to the old scan's.
        let mut batch = std::mem::take(&mut self.boards.in_service[b]);
        debug_assert!(batch.is_empty());
        batch.push(first);
        if self.cfg.batch.max_batch > 1
            && !self.boards.queue[b].is_empty()
        {
            let cap = self.cfg.batch.max_batch;
            let queue = &mut self.boards.queue[b];
            let mut kept = 0usize;
            for qi in 0..queue.len() {
                let r = queue[qi];
                if batch.len() < cap && r.model == model {
                    batch.push(r);
                } else {
                    queue[kept] = r;
                    kept += 1;
                }
            }
            queue.truncate(kept);
        }
        let p = self
            .profiles
            .get(model, self.boards.device[b])
            .expect("queued request must be servable");
        let switch = if self.boards.loaded[b] == model {
            0.0
        } else {
            self.boards.switches[b] += 1;
            self.boards.loaded[b] = model;
            p.reconfig_ms
        };
        let mut cost = switch + p.batch_ms(batch.len());
        // Straggler windows stretch sequences started inside them;
        // the guard keeps the fault-free float path untouched.
        if !self.cfg.faults.slowdowns.is_empty() {
            let factor = self.cfg.faults.slowdown_factor(b, now);
            if factor != 1.0 {
                cost *= factor;
            }
        }
        // Transient invocation failure draw (never taken — and the
        // stream never advanced — when the probability is 0).
        self.boards.service_failed[b] =
            self.cfg.faults.flaky_fail_prob > 0.0
                && self.flaky_rng.uniform()
                    < self.cfg.faults.flaky_fail_prob;
        // Keep the backlog estimator in sync: remove this sequence's
        // estimated contribution. Priority reordering and batch
        // amortisation can make realised costs diverge from the
        // enqueue-time estimates, so an empty queue resets the
        // estimator exactly instead of carrying a residue that would
        // bias SLO-aware dispatch against this board.
        if self.boards.queue[b].is_empty() {
            self.boards.backlog_ms[b] = 0.0;
            self.boards.tail_model[b] = model;
        } else {
            self.boards.backlog_ms[b] =
                (self.boards.backlog_ms[b] - cost).max(0.0);
        }
        self.boards.busy_ms[b] += cost;
        self.boards.free_at_ms[b] = now + cost;
        let clips = batch.len();
        self.boards.in_service[b] = batch;
        self.boards.batches[b] += 1;
        if self.rec.is_some() {
            // Stash the (straggler-scaled) switch/fill share of this
            // sequence for the reconfig/fill/service slice
            // decomposition its `Done` emits on the board track.
            let pre = switch + p.batch_ms(clips);
            let scale = if pre > 0.0 { cost / pre } else { 1.0 };
            self.boards.seq_start_ms[b] = now;
            self.boards.seq_reconfig_ms[b] = switch * scale;
            self.boards.seq_fill_ms[b] =
                p.fill_ms.max(0.0).min(p.batch_ms(clips)) * scale;
        }
        let epoch = self.boards.service_epoch[b];
        self.push(now + cost, EventKind::Done(b, epoch));
        if self.rec.is_some() {
            let busy = self
                .boards
                .in_service
                .iter()
                .filter(|s| !s.is_empty())
                .count();
            if let Some(r) = self.rec.as_deref_mut() {
                r.counter(PID_REQ, 0, "boards_busy", now * 1000.0,
                          busy as f64);
            }
        }
    }
}

/// Earliest estimated completion of one clip of `model` across live
/// boards — the admission-control estimate (the SLO-aware dispatch
/// formula, minimised over the fleet). `None` when no live board can
/// serve the model.
fn best_completion_est(profiles: &ProfileMatrix, boards: &Boards,
                       model: usize, now: f64, batch: &BatchCfg)
    -> Option<f64> {
    let mut best: Option<f64> = None;
    for b in 0..boards.len() {
        if !boards.up[b] {
            continue;
        }
        let Some(own) = boards.cost_after(
            profiles, b, boards.tail_model[b], model, batch)
        else {
            continue;
        };
        let start = if boards.in_service[b].is_empty() {
            now
        } else {
            boards.free_at_ms[b].max(now)
        };
        let est = start + boards.backlog_ms[b] + own;
        let better = match best {
            None => true,
            Some(e) => est < e,
        };
        if better {
            best = Some(est);
        }
    }
    best
}

/// Choose a board for `req` under `policy`. Boards whose device has no
/// feasible design for the request's model — and boards that are down
/// (crashed, not yet recovered) — are skipped; `None` means no board
/// can serve it right now.
fn dispatch(profiles: &ProfileMatrix, boards: &Boards,
            policy: Policy, rr_next: &mut usize, req: &Request,
            now: f64, batch: &BatchCfg) -> Option<usize> {
    let capable = |b: usize| {
        boards.up[b]
            && profiles.get(req.model, boards.device[b]).is_some()
    };
    match policy {
        Policy::RoundRobin => {
            // Advance the cursor past incapable boards (bounded by the
            // fleet size); the cursor moves exactly one capable board
            // per arrival, so the rotation stays fair.
            for _ in 0..boards.len() {
                let b = *rr_next % boards.len();
                *rr_next = (*rr_next + 1) % boards.len();
                if capable(b) {
                    return Some(b);
                }
            }
            None
        }
        // Load is measured in clips (queued + in flight), so a board
        // running a full batch reads as busier than one running a
        // single clip — the batch-aware load signal.
        Policy::LeastLoaded => (0..boards.len())
            .filter(|&b| capable(b))
            .min_by_key(|&b| {
                (boards.queue[b].len() + boards.in_service[b].len(), b)
            }),
        Policy::SloAware => {
            // Earliest estimated completion of this request: current
            // service tail + queued backlog + its own cost, which is
            // batch-aware (a clip joining its design's resident tail
            // pays only the marginal batched cost — see
            // `Boards::cost_after`). The backlog term is an
            // estimate under priority reordering, exact under FIFO.
            let mut best: Option<(f64, usize)> = None;
            for b in 0..boards.len() {
                if !boards.up[b] {
                    continue;
                }
                let Some(own) = boards.cost_after(
                    profiles, b, boards.tail_model[b], req.model,
                    batch)
                else {
                    continue;
                };
                let start = if boards.in_service[b].is_empty() {
                    now
                } else {
                    boards.free_at_ms[b].max(now)
                };
                let est = start + boards.backlog_ms[b] + own;
                let better = match best {
                    None => true,
                    Some((e, _)) => est < e,
                };
                if better {
                    best = Some((est, b));
                }
            }
            best.map(|(_, b)| b)
        }
    }
}

/// Index into `board.queue` of the request the discipline serves next.
//
// The `expect` documents the servability invariant of queued
// requests; see `Sim::try_enqueue`.
#[allow(clippy::disallowed_methods)]
fn pick_index(profiles: &ProfileMatrix, boards: &Boards, b: usize,
              queue: QueueDiscipline, batch: &BatchCfg) -> usize {
    match queue {
        QueueDiscipline::Fifo => 0,
        QueueDiscipline::Priority => {
            // Cheapest (service + switch) first; ties to the earlier
            // arrival (queue order). Queues are short, so the linear
            // scan is cheaper and more deterministic than a heap.
            let mut best = 0usize;
            let mut best_cost = f64::INFINITY;
            for (i, r) in boards.queue[b].iter().enumerate() {
                let c = boards
                    .cost_after(profiles, b, boards.loaded[b],
                                r.model, batch)
                    .expect("queued request must be servable");
                if c < best_cost {
                    best_cost = c;
                    best = i;
                }
            }
            best
        }
    }
}

/// Clips the next invocation sequence would carry if started now: the
/// discipline's pick plus every queued clip of the same model, capped
/// at `max_batch`. Only consulted while deciding whether to hold.
fn candidate_batch_len(profiles: &ProfileMatrix, boards: &Boards,
                       b: usize, queue: QueueDiscipline,
                       batch: &BatchCfg) -> usize {
    let pick = pick_index(profiles, boards, b, queue, batch);
    let model = boards.queue[b][pick].model;
    boards.queue[b]
        .iter()
        .filter(|r| r.model == model)
        .take(batch.max_batch)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix1(service_ms: f64, reconfig_ms: f64) -> ProfileMatrix {
        let mut m = ProfileMatrix::new(vec!["a".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms, reconfig_ms,
                                     fill_ms: 0.0 });
        m
    }

    fn fleet(n: usize) -> FleetCfg {
        FleetCfg {
            boards: (0..n)
                .map(|_| BoardSpec { device: 0, preload: 0 })
                .collect(),
            policy: Policy::LeastLoaded,
            queue: QueueDiscipline::Fifo,
            slo_ms: 100.0,
            batch: BatchCfg::default(),
            faults: FaultPlan::none(),
            resilience: ResilienceCfg::none(),
        }
    }

    #[test]
    fn empty_arrivals_yield_zero_metrics() {
        let m = matrix1(10.0, 5.0);
        let met = simulate_fleet(&m, &fleet(2), &[]);
        assert_eq!(met.completed, 0);
        assert_eq!(met.events, 0);
        assert_eq!(met.p99_ms, 0.0);
        assert_eq!(met.throughput_rps, 0.0);
    }

    #[test]
    fn back_to_back_requests_queue_fifo() {
        // 3 requests at t=0 on one board, 10 ms each: latencies are
        // exactly 10, 20, 30 ms, utilization 1.0.
        let m = matrix1(10.0, 5.0);
        let arr: Vec<Request> = (0..3)
            .map(|id| Request { id, model: 0, arrival_ms: 0.0 })
            .collect();
        let met = simulate_fleet(&m, &fleet(1), &arr);
        assert_eq!(met.completed, 3);
        assert_eq!(met.max_ms, 30.0);
        assert_eq!(met.p50_ms, 20.0);
        assert_eq!(met.makespan_ms, 30.0);
        assert_eq!(met.boards[0].utilization, 1.0);
        assert_eq!(met.switches, 0);
        // 2 events per request: arrival + completion.
        assert_eq!(met.events, 6);
    }

    #[test]
    fn least_loaded_spreads_simultaneous_arrivals() {
        let m = matrix1(10.0, 5.0);
        let arr: Vec<Request> = (0..4)
            .map(|id| Request { id, model: 0, arrival_ms: 0.0 })
            .collect();
        let met = simulate_fleet(&m, &fleet(4), &arr);
        assert_eq!(met.completed, 4);
        assert_eq!(met.max_ms, 10.0, "each board takes one request");
        for b in &met.boards {
            assert_eq!(b.completed, 1);
        }
    }

    #[test]
    fn model_switch_charged_once_until_next_change() {
        // Two models on one board: a→b→b charges one switch, and the
        // b requests after the first pay no reconfiguration.
        let mut m = ProfileMatrix::new(vec!["a".into(), "b".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 10.0, reconfig_ms: 7.0, fill_ms: 0.0 });
        m.set(1, 0, ServiceProfile { service_ms: 10.0, reconfig_ms: 7.0, fill_ms: 0.0 });
        let mut cfg = fleet(1);
        cfg.boards[0].preload = 0;
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 1, arrival_ms: 0.0 },
            Request { id: 2, model: 1, arrival_ms: 0.0 },
        ];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.switches, 1);
        // 10 + (7 + 10) + 10 of busy time, ending at t = 37.
        assert_eq!(met.makespan_ms, 37.0);
        assert_eq!(met.max_ms, 37.0);
    }

    #[test]
    fn priority_queue_serves_cheapest_first() {
        // Board busy with a long job; a long and a short job queue up.
        // Priority serves the short one first, FIFO the long one.
        let mut m = ProfileMatrix::new(vec!["long".into(), "short".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 20.0, reconfig_ms: 0.0, fill_ms: 0.0 });
        m.set(1, 0, ServiceProfile { service_ms: 2.0, reconfig_ms: 0.0, fill_ms: 0.0 });
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 0, arrival_ms: 1.0 },
            Request { id: 2, model: 1, arrival_ms: 2.0 },
        ];
        let mut cfg = fleet(1);
        cfg.queue = QueueDiscipline::Fifo;
        let fifo = simulate_fleet(&m, &cfg, &arr);
        cfg.queue = QueueDiscipline::Priority;
        let prio = simulate_fleet(&m, &cfg, &arr);
        // FIFO: short waits for both longs (20 + 20 + 2 - 2 = 40 ms).
        // Priority: short runs right after the first long (20 ms).
        assert_eq!(fifo.max_ms, 40.0);
        assert!(prio.mean_ms < fifo.mean_ms,
                "priority {} vs fifo {}", prio.mean_ms, fifo.mean_ms);
        assert_eq!(prio.completed, 3);
    }

    #[test]
    fn slo_aware_keeps_designs_resident() {
        // Two boards preloaded a/b; alternating idle-time arrivals.
        // SLO-aware routes each model to its resident board (0
        // switches); round-robin alternates and pays a switch on
        // every request after the first.
        let mut m = ProfileMatrix::new(vec!["a".into(), "b".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 5.0, reconfig_ms: 50.0, fill_ms: 0.0 });
        m.set(1, 0, ServiceProfile { service_ms: 5.0, reconfig_ms: 50.0, fill_ms: 0.0 });
        // a,a,b,b,… — deliberately misaligned with the board rotation
        // so round-robin cannot stay resident by accident.
        let arr: Vec<Request> = (0..8)
            .map(|id| Request {
                id,
                model: (id / 2) % 2,
                arrival_ms: 100.0 * id as f64,
            })
            .collect();
        let mut cfg = FleetCfg {
            boards: vec![BoardSpec { device: 0, preload: 0 },
                         BoardSpec { device: 0, preload: 1 }],
            policy: Policy::SloAware,
            queue: QueueDiscipline::Fifo,
            slo_ms: 100.0,
            batch: BatchCfg::default(),
            faults: FaultPlan::none(),
            resilience: ResilienceCfg::none(),
        };
        let slo = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(slo.switches, 0, "resident designs never reload");
        assert_eq!(slo.p99_ms, 5.0);
        cfg.policy = Policy::RoundRobin;
        let rr = simulate_fleet(&m, &cfg, &arr);
        assert!(rr.switches > 0);
        assert!(slo.switches <= rr.switches);
    }

    #[test]
    fn unservable_requests_are_dropped_and_counted() {
        let mut m = ProfileMatrix::new(vec!["a".into(), "b".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 5.0, reconfig_ms: 1.0, fill_ms: 0.0 });
        // model "b" has no feasible design anywhere.
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 1, arrival_ms: 1.0 },
        ];
        for policy in [Policy::RoundRobin, Policy::LeastLoaded,
                       Policy::SloAware] {
            let mut cfg = fleet(1);
            cfg.policy = policy;
            let met = simulate_fleet(&m, &cfg, &arr);
            assert_eq!(met.completed, 1, "{policy:?}");
            assert_eq!(met.dropped, 1, "{policy:?}");
        }
    }

    fn matrix_fill(service_ms: f64, fill_ms: f64) -> ProfileMatrix {
        let mut m = ProfileMatrix::new(vec!["a".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms, reconfig_ms: 5.0,
                                     fill_ms });
        m
    }

    #[test]
    fn batch_ms_amortises_fill() {
        let p = ServiceProfile { service_ms: 10.0, reconfig_ms: 5.0,
                                 fill_ms: 4.0 };
        assert_eq!(p.batch_ms(0), 10.0);
        assert_eq!(p.batch_ms(1), 10.0);
        assert_eq!(p.batch_ms(2), 16.0, "10 + one 6 ms marginal clip");
        assert_eq!(p.batch_ms(4), 28.0, "10 + three 6 ms marginal clips");
        // fill >= service clamps the marginal cost at zero.
        let degenerate = ServiceProfile { service_ms: 3.0,
                                          reconfig_ms: 0.0,
                                          fill_ms: 9.0 };
        assert_eq!(degenerate.batch_ms(5), 3.0);
    }

    #[test]
    fn opportunistic_batching_groups_queued_clips() {
        // 3 clips at t=0 on one board, service 10 / fill 4, batch cap
        // 4, no hold window. The first clip starts alone (nothing else
        // queued yet at its event); the two clips queued behind it run
        // as one sequence: 10 + (10 + 6) = 26 ms makespan vs 30 ms
        // unbatched.
        let m = matrix_fill(10.0, 4.0);
        let mut cfg = fleet(1);
        cfg.batch = BatchCfg::new(4, 0.0);
        let arr: Vec<Request> = (0..3)
            .map(|id| Request { id, model: 0, arrival_ms: 0.0 })
            .collect();
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 3);
        assert_eq!(met.batches, 2, "1-clip + 2-clip sequences");
        assert_eq!(met.makespan_ms, 26.0);
        assert_eq!(met.max_ms, 26.0);
        // 3 arrivals + 2 completions, no hold events.
        assert_eq!(met.events, 5);
        let unbatched = simulate_fleet(&m, &fleet(1), &arr);
        assert_eq!(unbatched.makespan_ms, 30.0);
        assert_eq!(unbatched.batches, 3);
    }

    #[test]
    fn hold_window_fills_batch_from_later_arrival() {
        // Batch cap 2 with a 5 ms hold: the t=0 clip waits, the t=2
        // clip fills the batch, and the pair starts immediately at
        // t=2 (cost 16 ms -> done at 18). The stale hold timer at t=5
        // is a counted no-op event.
        let m = matrix_fill(10.0, 4.0);
        let mut cfg = fleet(1);
        cfg.batch = BatchCfg::new(2, 5.0);
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 0, arrival_ms: 2.0 },
        ];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 2);
        assert_eq!(met.batches, 1, "one 2-clip sequence");
        assert_eq!(met.makespan_ms, 18.0);
        assert_eq!(met.max_ms, 18.0, "head clip: 2 ms hold + 16 ms");
        assert_eq!(met.mean_ms, 17.0, "(18 + 16) / 2");
        // 2 arrivals + 1 expired (stale) hold + 1 completion.
        assert_eq!(met.events, 4);
    }

    #[test]
    fn hold_expiry_starts_short_batch() {
        // A lone clip under a 4-wide batch cap with a 5 ms hold: no
        // batchmates ever arrive, the timer expires, and the clip runs
        // alone having paid the full hold window.
        let m = matrix_fill(10.0, 4.0);
        let mut cfg = fleet(1);
        cfg.batch = BatchCfg::new(4, 5.0);
        let arr = vec![Request { id: 0, model: 0, arrival_ms: 0.0 }];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 1);
        assert_eq!(met.batches, 1);
        assert_eq!(met.max_ms, 15.0, "5 ms hold + 10 ms service");
        assert_eq!(met.events, 3);
    }

    #[test]
    fn batches_never_mix_models() {
        // a, b, a queued: the b sequence must not absorb the trailing
        // a clip, so three sequences run and two switches are paid.
        let mut m = ProfileMatrix::new(vec!["a".into(), "b".into()],
                                       vec!["dev".into()]);
        for i in 0..2 {
            m.set(i, 0, ServiceProfile { service_ms: 10.0,
                                         reconfig_ms: 7.0,
                                         fill_ms: 4.0 });
        }
        let mut cfg = fleet(1);
        cfg.batch = BatchCfg::new(4, 0.0);
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 1, arrival_ms: 0.0 },
            Request { id: 2, model: 0, arrival_ms: 0.0 },
        ];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 3);
        assert_eq!(met.batches, 3);
        assert_eq!(met.switches, 2, "b loads, then a reloads");
        // 10 + (7 + 10) + (7 + 10) of busy time.
        assert_eq!(met.makespan_ms, 44.0);
    }

    #[test]
    fn crash_fails_over_in_flight_and_queued_work() {
        // Two boards, three clips at t=0: board 0 crashes at t=5 with
        // one clip in flight and one queued. Both fail over to board
        // 1 and finish behind its own clip: latencies 10/20/30, the
        // interrupted work's unfinished remainder is refunded.
        let m = matrix1(10.0, 5.0);
        let mut cfg = fleet(2);
        cfg.faults.crashes.push(faults::Crash {
            board: 0, at_ms: 5.0, recover_ms: f64::INFINITY });
        let arr: Vec<Request> = (0..3)
            .map(|id| Request { id, model: 0, arrival_ms: 0.0 })
            .collect();
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 3);
        assert_eq!(met.failed, 0);
        assert_eq!(met.failovers, 2, "in-flight clip + queued clip");
        assert_eq!(met.dropped, 0);
        assert_eq!(met.max_ms, 30.0);
        assert_eq!(met.makespan_ms, 30.0);
        assert_eq!(met.boards[0].busy_ms, 5.0, "remainder refunded");
        assert_eq!(met.boards[0].completed, 0);
        assert_eq!(met.boards[1].completed, 3);
        // 3 arrivals + crash + stale done + 3 completions.
        assert_eq!(met.events, 8);
        assert_eq!(met.goodput_p99_ms.to_bits(), met.p99_ms.to_bits());
    }

    #[test]
    fn crash_without_survivors_fails_requests() {
        let m = matrix1(10.0, 5.0);
        let mut cfg = fleet(1);
        cfg.faults.crashes.push(faults::Crash {
            board: 0, at_ms: 5.0, recover_ms: f64::INFINITY });
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 0, arrival_ms: 0.0 },
            Request { id: 2, model: 0, arrival_ms: 6.0 },
        ];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 0);
        assert_eq!(met.failed, 2, "in-flight + queued lost for good");
        assert_eq!(met.dropped, 1, "arrival with no live board");
        assert_eq!(met.failovers, 2);
        assert_eq!(met.p99_ms, 0.0, "empty set: zero, not NaN");
        assert!(met.goodput_p99_ms.is_infinite(),
                "losses dominate the goodput tail");
    }

    #[test]
    fn recovered_board_serves_retries_cold() {
        // One board, one clip: the crash strands the failover (no
        // live board), two backed-off retries still find the fleet
        // down, and the third lands after the t=20 recovery — paying
        // a full reconfiguration because recovery is cold.
        let m = matrix1(10.0, 5.0);
        let mut cfg = fleet(1);
        cfg.faults.crashes.push(faults::Crash {
            board: 0, at_ms: 5.0, recover_ms: 20.0 });
        cfg.resilience.retries = 3;
        let arr = vec![Request { id: 0, model: 0, arrival_ms: 0.0 }];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 1);
        assert_eq!(met.failed, 0);
        assert_eq!(met.failovers, 1);
        assert_eq!(met.retries, 3);
        assert_eq!(met.switches, 1, "cold recovery reconfigures");
        // Backoff: 5*(0.5..1) + 10*(0.5..1) + 20*(0.5..1) after t=5,
        // then 15 ms reconfig + service.
        assert!(met.max_ms >= 35.0 && met.max_ms < 55.0,
                "retry lands after recovery: {}", met.max_ms);
    }

    #[test]
    fn straggler_window_stretches_sequences() {
        let m = matrix1(10.0, 5.0);
        let mut cfg = fleet(1);
        cfg.faults.slowdowns.push(faults::Slowdown {
            board: 0, from_ms: 0.0, to_ms: 100.0, factor: 2.0 });
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 0, arrival_ms: 50.0 },
            Request { id: 2, model: 0, arrival_ms: 150.0 },
        ];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 3);
        assert_eq!(met.max_ms, 20.0, "inside the window: 2x service");
        assert_eq!(met.p50_ms, 20.0);
        assert_eq!(met.makespan_ms, 160.0,
                   "outside the window: full speed again");
    }

    #[test]
    fn deadline_times_out_queued_work_and_retries() {
        // Service 10 with a 5 ms queue deadline: the second clip
        // times out while the first is served, then lands on its
        // backed-off retry.
        let m = matrix1(10.0, 5.0);
        let mut cfg = fleet(1);
        cfg.resilience.deadline_ms = 5.0;
        cfg.resilience.retries = 1;
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 0, arrival_ms: 0.0 },
        ];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 2);
        assert_eq!(met.timeouts, 1);
        assert_eq!(met.retries, 1);
        assert_eq!(met.failed, 0);
        assert!(met.max_ms >= 22.0 && met.max_ms < 25.0,
                "retried clip: backoff in [2.5, 5) + 10 ms service: {}",
                met.max_ms);
        // Without a retry budget the timeout is terminal and the
        // goodput tail goes infinite.
        cfg.resilience.retries = 0;
        let met0 = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met0.completed, 1);
        assert_eq!(met0.failed, 1);
        assert!(met0.goodput_p99_ms.is_infinite());
        assert_eq!(met0.p99_ms, 10.0, "raw p99 hides the loss");
    }

    #[test]
    fn transient_failures_burn_retries_then_fail() {
        let m = matrix1(10.0, 5.0);
        let mut cfg = fleet(1);
        cfg.faults.flaky_fail_prob = 1.0;
        cfg.resilience.retries = 2;
        let arr = vec![Request { id: 0, model: 0, arrival_ms: 0.0 }];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 0);
        assert_eq!(met.failed, 1);
        assert_eq!(met.retries, 2);
        assert_eq!(met.batches, 3, "every attempt spent board time");
        assert_eq!(met.boards[0].busy_ms, 30.0);
        assert!(met.goodput_p99_ms.is_infinite());
    }

    #[test]
    fn admission_control_sheds_on_estimated_deadline_blowout() {
        // One board, service 10, deadline 12: the first clip fits
        // (est 10), the other two would complete at 20+ and are shed
        // at the door instead of blowing the SLO in the queue.
        let m = matrix1(10.0, 5.0);
        let mut cfg = fleet(1);
        cfg.resilience.deadline_ms = 12.0;
        cfg.resilience.shed = true;
        let arr: Vec<Request> = (0..3)
            .map(|id| Request { id, model: 0, arrival_ms: 0.0 })
            .collect();
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 1);
        assert_eq!(met.shed, 2);
        assert_eq!(met.dropped, 0);
        assert_eq!(met.max_ms, 10.0);
        assert_eq!(met.goodput_p99_ms, 10.0,
                   "shed requests are not goodput failures");
    }

    #[test]
    fn saturated_arrival_downgrades_to_fallback_variant() {
        let mut m = ProfileMatrix::new(
            vec!["full".into(), "lite".into()], vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 20.0,
                                     reconfig_ms: 2.0, fill_ms: 0.0 });
        m.set(1, 0, ServiceProfile { service_ms: 5.0,
                                     reconfig_ms: 2.0, fill_ms: 0.0 });
        let mut cfg = fleet(1);
        cfg.resilience.deadline_ms = 12.0;
        cfg.resilience.shed = true;
        cfg.resilience.fallback = vec![Some(1), None];
        let arr = vec![Request { id: 0, model: 0, arrival_ms: 0.0 }];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 1);
        assert_eq!(met.fallbacks, 1, "full would miss, lite fits");
        assert_eq!(met.shed, 0);
        assert_eq!(met.switches, 1);
        assert_eq!(met.max_ms, 7.0, "reconfig + lite service");
    }

    #[test]
    fn fault_runs_replay_bit_identically() {
        let m = matrix1(10.0, 5.0);
        let mut cfg = fleet(2);
        cfg.faults.crashes.push(faults::Crash {
            board: 0, at_ms: 5.0, recover_ms: 40.0 });
        cfg.faults.flaky_fail_prob = 0.5;
        cfg.faults.seed = 9;
        cfg.resilience.retries = 4;
        cfg.resilience.deadline_ms = 25.0;
        cfg.resilience.seed = 9;
        let arr: Vec<Request> = (0..20)
            .map(|id| Request { id, model: 0,
                                arrival_ms: 2.0 * id as f64 })
            .collect();
        let a = simulate_fleet(&m, &cfg, &arr);
        let b = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.failed, b.failed);
        assert_eq!(a.retries, b.retries);
        assert_eq!(a.events, b.events);
        assert_eq!(a.p99_ms.to_bits(), b.p99_ms.to_bits());
        assert_eq!(a.goodput_p99_ms.to_bits(), b.goodput_p99_ms.to_bits());
        assert_eq!(a.makespan_ms.to_bits(), b.makespan_ms.to_bits());
    }

    #[test]
    fn stats_pipeline_observes_without_changing_metrics() {
        // 3 clips at t=0, 10 ms each, 10 ms windows: window 0 holds
        // the arrivals, windows 1..=3 one completion each (the t=10
        // completion lands *after* the boundary closes window 0).
        let m = matrix1(10.0, 5.0);
        let arr: Vec<Request> = (0..3)
            .map(|id| Request { id, model: 0, arrival_ms: 0.0 })
            .collect();
        let plain = simulate_fleet(&m, &fleet(1), &arr);
        let mut stats = StreamStats::new(crate::obs::StatsCfg {
            window_ms: 10.0, shards: 1, slo_target: 0.99 });
        let met = simulate_fleet_obs(&m, &fleet(1), &arr, None,
                                     Some(&mut stats));
        assert_eq!(format!("{plain:?}"), format!("{met:?}"),
                   "attaching stats changes no metric bit");
        let rows = stats.rows();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].arrivals, 3);
        assert_eq!(rows[0].completions, 0);
        assert_eq!(rows.iter().map(|r| r.completions).sum::<u64>(), 3);
        assert!(rows.iter().all(|r| r.good == r.completions),
                "all under the 100 ms SLO");
        assert!(stats.breaches().is_empty());
        assert_eq!(stats.engine_events, met.events as u64);
        assert!(stats.engine_wall_s > 0.0, "self-profiling stamped");
    }

    #[test]
    fn calendar_queue_pops_in_reference_heap_order() {
        use std::collections::BinaryHeap;
        // Drive a CalendarQueue and the reference BinaryHeap through
        // an identical DES-shaped schedule — a burst of 4-way exact
        // time ties, then pops interleaved with pushes at/after the
        // popped time (same-time events, near-future completions and
        // far-future recoveries spanning many calendar laps). The
        // queue is deliberately undersized (4 buckets for dozens of
        // events) so growth and bucket aliasing are both exercised.
        // Pop sequences must agree bit-for-bit.
        let mut cal = CalendarQueue::for_horizon(4, 10.0);
        let mut heap: BinaryHeap<Event> = BinaryHeap::new();
        let mut seq = 0u64;
        for i in 0..40usize {
            let ev = Event {
                t_ms: (i / 4) as f64 * 2.5,
                seq,
                kind: EventKind::Arrival(i),
            };
            seq += 1;
            cal.push(ev);
            heap.push(ev);
        }
        let mut popped = 0usize;
        while let Some(h) = heap.pop() {
            let c = cal.pop().expect("calendar agrees on emptiness");
            assert_eq!(h.t_ms.to_bits(), c.t_ms.to_bits(),
                       "pop {popped}: time diverged");
            assert_eq!(h.seq, c.seq, "pop {popped}: tie-break diverged");
            popped += 1;
            if popped % 3 == 0 && seq < 120 {
                // DES pushes land at or after the time just popped.
                for dt in [0.0, 7.5, 400.0] {
                    let ev = Event {
                        t_ms: h.t_ms + dt,
                        seq,
                        kind: EventKind::Done(0, 0),
                    };
                    seq += 1;
                    cal.push(ev);
                    heap.push(ev);
                }
            }
        }
        assert!(cal.pop().is_none(), "both drain together");
        assert!(popped >= 120, "interleaved schedule ran: {popped}");
    }

    #[test]
    fn calendar_queue_finds_events_beyond_one_lap() {
        // A sparse schedule whose events sit many laps past the
        // cursor (a lone far-future recovery is the simulator case):
        // the global-scan fallback must find them in exact order.
        let mut cal = CalendarQueue::for_horizon(4, 10.0);
        let times = [0.0, 1e6, 1e6 + 1.0, 5.0, 2.5e8];
        for (i, &t) in times.iter().enumerate() {
            cal.push(Event {
                t_ms: t,
                seq: i as u64,
                kind: EventKind::Arrival(i),
            });
        }
        let mut sorted = times;
        sorted.sort_by(|a, b| a.total_cmp(b));
        for &want in &sorted {
            let got = cal.pop().expect("event present");
            assert_eq!(got.t_ms.to_bits(), want.to_bits());
        }
        assert!(cal.pop().is_none());
    }

    #[test]
    fn cost_after_counts_joinable_tail_clips() {
        // service 10 / fill 4 / reconfig 5; batch cap 2.
        let m = matrix_fill(10.0, 4.0);
        let batch2 = BatchCfg::new(2, 0.0);
        let specs = [BoardSpec { device: 0, preload: 0 }];
        let mut boards = Boards::new(&specs);
        // Idle with an empty queue: a joining clip opens its own
        // sequence and pays the full per-clip cost — the old
        // estimator wrongly billed the 6 ms fill-free marginal here.
        assert_eq!(boards.cost_after(&m, 0, 0, 0, &batch2),
                   Some(10.0));
        // One clip in the tail batch: the next one rides it at the
        // marginal cost (batch_ms(2) - batch_ms(1) = 6).
        boards.queue[0].push_back(
            Request { id: 0, model: 0, arrival_ms: 0.0 });
        assert_eq!(boards.cost_after(&m, 0, 0, 0, &batch2),
                   Some(6.0));
        // Tail batch at the cap: the third clip opens a new sequence
        // and pays full fill again.
        boards.queue[0].push_back(
            Request { id: 1, model: 0, arrival_ms: 0.0 });
        assert_eq!(boards.cost_after(&m, 0, 0, 0, &batch2),
                   Some(10.0));
        // Mismatched design: full service + reconfiguration.
        assert_eq!(boards.cost_after(&m, 0, NOTHING, 0, &batch2),
                   Some(15.0));
        // Batching off: plain service cost, queue ignored.
        assert_eq!(
            boards.cost_after(&m, 0, 0, 0, &BatchCfg::default()),
            Some(10.0));
    }

    #[test]
    fn full_tail_batch_routes_to_the_cheaper_board() {
        // The cost_after regression pin. Two boards on one device:
        // b0 preloads m0 (service 10 / fill 4), b1 preloads m1
        // (service 20 / fill 0); reconfig 1; SLO-aware dispatch with
        // batch cap 2. A0(m1), A1..A3(m0) at t=0 route identically
        // under the old and fixed estimators (b1 takes A0; b0 serves
        // A1 and queues [A2, A3] — a tail batch exactly at the cap).
        // A4(m0) at t=1 is the discriminating dispatch: the old
        // estimator still priced b0 at the fill-free marginal
        // (est 10 + 12 + 6 = 28 < 31 via b1) and mis-routed A4
        // behind the full batch, where it started a fresh sequence
        // at t=26 and finished at 36 (35 ms latency, 0 switches).
        // Counting joinable tail clips prices b0 honestly
        // (10 + 16 + 10 = 36 > 31), so A4 goes to b1, pays the m0
        // reload there and finishes at t=31 — a 30 ms latency.
        let mut m = ProfileMatrix::new(vec!["m0".into(), "m1".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 10.0,
                                     reconfig_ms: 1.0, fill_ms: 4.0 });
        m.set(1, 0, ServiceProfile { service_ms: 20.0,
                                     reconfig_ms: 1.0, fill_ms: 0.0 });
        let cfg = FleetCfg {
            boards: vec![BoardSpec { device: 0, preload: 0 },
                         BoardSpec { device: 0, preload: 1 }],
            policy: Policy::SloAware,
            queue: QueueDiscipline::Fifo,
            slo_ms: 100.0,
            batch: BatchCfg::new(2, 0.0),
            faults: FaultPlan::none(),
            resilience: ResilienceCfg::none(),
        };
        let arr = vec![
            Request { id: 0, model: 1, arrival_ms: 0.0 },
            Request { id: 1, model: 0, arrival_ms: 0.0 },
            Request { id: 2, model: 0, arrival_ms: 0.0 },
            Request { id: 3, model: 0, arrival_ms: 0.0 },
            Request { id: 4, model: 0, arrival_ms: 1.0 },
        ];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 5);
        assert_eq!(met.switches, 1, "b1 reloads m0 for A4");
        assert_eq!(met.batches, 4);
        assert_eq!(met.max_ms, 30.0,
                   "the old estimator parked A4 behind a full batch \
                    for a 35 ms tail");
        assert_eq!(met.makespan_ms, 31.0);
        assert_eq!(met.events, 9, "5 arrivals + 4 completions");
    }

    #[test]
    fn policy_and_queue_parse() {
        assert_eq!(Policy::parse("rr"), Some(Policy::RoundRobin));
        assert_eq!(Policy::parse("slo-aware"), Some(Policy::SloAware));
        assert_eq!(Policy::parse("least-loaded"),
                   Some(Policy::LeastLoaded));
        assert!(Policy::parse("nope").is_none());
        assert_eq!(QueueDiscipline::parse("fifo"),
                   Some(QueueDiscipline::Fifo));
        assert_eq!(QueueDiscipline::parse("priority"),
                   Some(QueueDiscipline::Priority));
        assert!(QueueDiscipline::parse("lifo").is_none());
    }
}
