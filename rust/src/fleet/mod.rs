//! Fleet serving — the serving-scale axis on top of the per-design
//! toolflow (ROADMAP north star: heavy HAR traffic, not single clips).
//!
//! HARFLOW3D (§V) optimises one design for one clip's latency; serving
//! millions of users adds the dimensions the throughput-oriented
//! siblings (fpgaHART, FPGA-QHAR) optimise for: queueing, dispatch,
//! and fleet sizing. This module provides
//!
//! * a **deterministic event-driven simulator** over a fleet of FPGA
//!   boards, each serving one loaded design at a time with a per-board
//!   FIFO or priority queue, charging `sim::DesignLatencyProfile`
//!   service latency per clip and the design-switch (reconfiguration)
//!   cost when a board changes design — arrivals come from a seeded
//!   Poisson process ([`arrivals::poisson`]) or a trace file
//!   ([`arrivals::from_trace`]), and every tie is broken by sequence
//!   number so a seed pins the run bit-for-bit;
//! * an **SLO-driven capacity planner** ([`planner::plan`]) that
//!   consumes `report::sweep` design points and searches board counts
//!   × design assignments for the cheapest fleet meeting a p99 SLO at
//!   a target arrival rate.

pub mod arrivals;
pub mod planner;

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use crate::util::stats::percentile_sorted;

// ------------------------------------------------------------------------
// Profiles: what the simulator charges per request
// ------------------------------------------------------------------------

/// Per (model, device) serving numbers — a lean projection of
/// [`crate::sim::DesignLatencyProfile`] (which carries names and
/// provenance; the inner loop only needs the two latencies).
#[derive(Debug, Clone, Copy)]
pub struct ServiceProfile {
    /// Per-clip service latency (ms) of the optimised design.
    pub service_ms: f64,
    /// Cost (ms) of loading this design onto a board that currently
    /// holds a different one.
    pub reconfig_ms: f64,
}

/// The model × device profile grid the simulator and planner consume.
/// `None` marks an infeasible design point (model does not fit the
/// device); `costs[d]` is the relative board cost of device `d`.
#[derive(Debug, Clone)]
pub struct ProfileMatrix {
    pub models: Vec<String>,
    pub devices: Vec<String>,
    /// Relative board cost per device (see [`planner::board_cost`]).
    pub costs: Vec<f64>,
    grid: Vec<Vec<Option<ServiceProfile>>>,
}

impl ProfileMatrix {
    /// Empty grid (all points infeasible, unit costs).
    pub fn new(models: Vec<String>, devices: Vec<String>)
        -> ProfileMatrix {
        let grid = vec![vec![None; devices.len()]; models.len()];
        let costs = vec![1.0; devices.len()];
        ProfileMatrix { models, devices, costs, grid }
    }

    pub fn set(&mut self, model: usize, device: usize, p: ServiceProfile) {
        self.grid[model][device] = Some(p);
    }

    pub fn get(&self, model: usize, device: usize)
        -> Option<ServiceProfile> {
        self.grid[model][device]
    }

    pub fn model_index(&self, name: &str) -> Option<usize> {
        self.models.iter().position(|m| m == name)
    }

    pub fn device_index(&self, name: &str) -> Option<usize> {
        self.devices.iter().position(|d| d == name)
    }
}

// ------------------------------------------------------------------------
// Requests, boards, policies
// ------------------------------------------------------------------------

/// One inference request: a clip of `model` arriving at `arrival_ms`.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub id: usize,
    /// Row into the [`ProfileMatrix`].
    pub model: usize,
    pub arrival_ms: f64,
}

/// One board of the fleet: a device instance with an initially loaded
/// design (set by the planner / CLI, so a warm fleet pays no switch on
/// its first matching request).
#[derive(Debug, Clone, Copy)]
pub struct BoardSpec {
    /// Column into the [`ProfileMatrix`].
    pub device: usize,
    /// Initially loaded design (model row).
    pub preload: usize,
}

/// Which board a new arrival is queued on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Arrival `i` goes to board `i mod fleet size`.
    RoundRobin,
    /// Fewest requests queued + in service; ties to the lowest index.
    LeastLoaded,
    /// Earliest estimated completion, accounting for the board's
    /// backlog and the design-switch cost a mismatched board would
    /// pay — the policy that keeps designs resident where possible.
    SloAware,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "rr" | "round-robin" => Some(Policy::RoundRobin),
            "ll" | "least-loaded" => Some(Policy::LeastLoaded),
            "slo" | "slo-aware" => Some(Policy::SloAware),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLoaded => "least-loaded",
            Policy::SloAware => "slo-aware",
        }
    }
}

/// Per-board queue discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueDiscipline {
    /// Arrival order.
    Fifo,
    /// Cheapest work first (shortest service + switch on this board;
    /// ties to the earlier arrival) — trades a long clip's tail for
    /// the short clips' percentiles.
    Priority,
}

impl QueueDiscipline {
    pub fn parse(s: &str) -> Option<QueueDiscipline> {
        match s {
            "fifo" => Some(QueueDiscipline::Fifo),
            "priority" | "sjf" => Some(QueueDiscipline::Priority),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            QueueDiscipline::Fifo => "fifo",
            QueueDiscipline::Priority => "priority",
        }
    }
}

/// Fleet composition + serving policy for one simulation run.
#[derive(Debug, Clone)]
pub struct FleetCfg {
    pub boards: Vec<BoardSpec>,
    pub policy: Policy,
    pub queue: QueueDiscipline,
    /// The latency objective (ms); violations are counted per request.
    pub slo_ms: f64,
}

// ------------------------------------------------------------------------
// Metrics
// ------------------------------------------------------------------------

/// Per-board outcome of a simulation run.
#[derive(Debug, Clone)]
pub struct BoardReport {
    pub device: usize,
    pub completed: usize,
    pub switches: usize,
    pub busy_ms: f64,
    /// busy time / makespan.
    pub utilization: f64,
}

/// Fleet-level outcome of a simulation run. All fields are
/// deterministic functions of (profiles, cfg, arrivals) — no wall
/// clock anywhere — so a fixed seed reproduces them bit-for-bit.
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    pub completed: usize,
    /// Requests no board could serve (their model fits no board's
    /// device) — always 0 for planner-built fleets.
    pub dropped: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// Completed requests per second of simulated time.
    pub throughput_rps: f64,
    /// Last completion time (simulated ms; arrivals start near 0).
    pub makespan_ms: f64,
    pub slo_ms: f64,
    pub slo_violations: usize,
    pub switches: usize,
    /// Simulator events processed (arrivals + completions) — the
    /// bench's events/sec numerator.
    pub events: usize,
    pub boards: Vec<BoardReport>,
}

impl FleetMetrics {
    pub fn mean_utilization(&self) -> f64 {
        if self.boards.is_empty() {
            return 0.0;
        }
        self.boards.iter().map(|b| b.utilization).sum::<f64>()
            / self.boards.len() as f64
    }

    pub fn slo_met(&self) -> bool {
        self.p99_ms <= self.slo_ms
    }
}

// ------------------------------------------------------------------------
// Event-driven simulator
// ------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum EventKind {
    /// Index into the arrivals slice.
    Arrival(usize),
    /// Board finished its in-service request.
    Done(usize),
}

/// Heap event. Ordered so `BinaryHeap::pop` yields the *earliest*
/// time; equal times break by insertion sequence, which makes the
/// event order — and therefore the whole run — independent of float
/// coincidences and fully deterministic.
#[derive(Debug, Clone, Copy)]
struct Event {
    t_ms: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for Event {
    fn cmp(&self, o: &Self) -> Ordering {
        // Reversed: the max-heap pops the minimum (time, seq).
        o.t_ms.total_cmp(&self.t_ms).then_with(|| o.seq.cmp(&self.seq))
    }
}

/// Live board state during a run.
struct BoardState {
    device: usize,
    /// Currently loaded design (model row).
    loaded: usize,
    /// Design loaded once the whole queue has drained — the backlog
    /// estimator's switch-cost anchor.
    tail_model: usize,
    queue: VecDeque<Request>,
    in_service: Option<Request>,
    free_at_ms: f64,
    /// Estimated queued work (service + expected switches), ms.
    backlog_ms: f64,
    busy_ms: f64,
    completed: usize,
    switches: usize,
}

impl BoardState {
    /// Cost of serving `model` right after `prev` on this board.
    fn cost_after(&self, profiles: &ProfileMatrix, prev: usize,
                  model: usize) -> Option<f64> {
        let p = profiles.get(model, self.device)?;
        let switch = if prev == model { 0.0 } else { p.reconfig_ms };
        Some(p.service_ms + switch)
    }
}

/// Run the fleet through a sorted arrival stream. Panics if `arrivals`
/// is not sorted by `arrival_ms` (the arrival constructors guarantee
/// it) or the fleet is empty.
pub fn simulate_fleet(profiles: &ProfileMatrix, cfg: &FleetCfg,
                      arrivals: &[Request]) -> FleetMetrics {
    assert!(!cfg.boards.is_empty(), "fleet has no boards");
    debug_assert!(arrivals.windows(2)
                      .all(|w| w[0].arrival_ms <= w[1].arrival_ms),
                  "arrivals must be time-sorted");

    let mut boards: Vec<BoardState> = cfg
        .boards
        .iter()
        .map(|b| BoardState {
            device: b.device,
            loaded: b.preload,
            tail_model: b.preload,
            queue: VecDeque::new(),
            in_service: None,
            free_at_ms: 0.0,
            backlog_ms: 0.0,
            busy_ms: 0.0,
            completed: 0,
            switches: 0,
        })
        .collect();

    let mut heap: BinaryHeap<Event> = BinaryHeap::with_capacity(
        arrivals.len() + boards.len());
    let mut seq = 0u64;
    for (i, r) in arrivals.iter().enumerate() {
        heap.push(Event { t_ms: r.arrival_ms, seq, kind: EventKind::Arrival(i) });
        seq += 1;
    }

    let mut latencies: Vec<f64> = Vec::with_capacity(arrivals.len());
    let mut dropped = 0usize;
    let mut events = 0usize;
    let mut rr_next = 0usize;
    let mut makespan_ms = 0.0f64;

    while let Some(ev) = heap.pop() {
        events += 1;
        let now = ev.t_ms;
        match ev.kind {
            EventKind::Arrival(i) => {
                let req = arrivals[i];
                let Some(b) = dispatch(profiles, &boards, cfg.policy,
                                       &mut rr_next, &req, now)
                else {
                    dropped += 1;
                    continue;
                };
                let board = &mut boards[b];
                let est = board
                    .cost_after(profiles, board.tail_model, req.model)
                    .expect("dispatch returned a capable board");
                board.backlog_ms += est;
                board.tail_model = req.model;
                board.queue.push_back(req);
                if board.in_service.is_none() {
                    start_next(profiles, board, cfg.queue, now, &mut heap,
                               &mut seq, b);
                }
            }
            EventKind::Done(b) => {
                let board = &mut boards[b];
                let req = board
                    .in_service
                    .take()
                    .expect("completion without in-service request");
                board.completed += 1;
                latencies.push(now - req.arrival_ms);
                makespan_ms = makespan_ms.max(now);
                if !board.queue.is_empty() {
                    start_next(profiles, board, cfg.queue, now, &mut heap,
                               &mut seq, b);
                }
            }
        }
    }

    let slo_violations =
        latencies.iter().filter(|&&l| l > cfg.slo_ms).count();
    let mean_ms = crate::util::stats::mean(&latencies);
    // One sort serves every percentile and the max (metrics are on the
    // benched path — events/sec should measure the simulator, not
    // repeated bookkeeping sorts).
    let mut sorted = latencies;
    sorted.sort_by(|a, b| a.total_cmp(b));
    let board_reports: Vec<BoardReport> = boards
        .iter()
        .map(|b| BoardReport {
            device: b.device,
            completed: b.completed,
            switches: b.switches,
            busy_ms: b.busy_ms,
            utilization: if makespan_ms > 0.0 {
                b.busy_ms / makespan_ms
            } else {
                0.0
            },
        })
        .collect();
    FleetMetrics {
        completed: sorted.len(),
        dropped,
        p50_ms: percentile_sorted(&sorted, 50.0),
        p95_ms: percentile_sorted(&sorted, 95.0),
        p99_ms: percentile_sorted(&sorted, 99.0),
        mean_ms,
        max_ms: sorted.last().copied().unwrap_or(0.0),
        throughput_rps: if makespan_ms > 0.0 {
            sorted.len() as f64 / (makespan_ms / 1e3)
        } else {
            0.0
        },
        makespan_ms,
        slo_ms: cfg.slo_ms,
        slo_violations,
        switches: boards.iter().map(|b| b.switches).sum(),
        events,
        boards: board_reports,
    }
}

/// Choose a board for `req` under `policy`. Boards whose device has no
/// feasible design for the request's model are skipped; `None` means
/// no board can serve it (the request is dropped and counted).
fn dispatch(profiles: &ProfileMatrix, boards: &[BoardState],
            policy: Policy, rr_next: &mut usize, req: &Request,
            now: f64) -> Option<usize> {
    let capable =
        |b: &BoardState| profiles.get(req.model, b.device).is_some();
    match policy {
        Policy::RoundRobin => {
            // Advance the cursor past incapable boards (bounded by the
            // fleet size); the cursor moves exactly one capable board
            // per arrival, so the rotation stays fair.
            for _ in 0..boards.len() {
                let b = *rr_next % boards.len();
                *rr_next = (*rr_next + 1) % boards.len();
                if capable(&boards[b]) {
                    return Some(b);
                }
            }
            None
        }
        Policy::LeastLoaded => boards
            .iter()
            .enumerate()
            .filter(|(_, b)| capable(b))
            .min_by_key(|(i, b)| {
                (b.queue.len() + b.in_service.is_some() as usize, *i)
            })
            .map(|(i, _)| i),
        Policy::SloAware => {
            // Earliest estimated completion of this request: current
            // service tail + queued backlog + its own (service +
            // switch-if-mismatched) cost. The backlog term is an
            // estimate under priority reordering, exact under FIFO.
            let mut best: Option<(f64, usize)> = None;
            for (i, b) in boards.iter().enumerate() {
                let Some(own) =
                    b.cost_after(profiles, b.tail_model, req.model)
                else {
                    continue;
                };
                let start = if b.in_service.is_some() {
                    b.free_at_ms.max(now)
                } else {
                    now
                };
                let est = start + b.backlog_ms + own;
                let better = match best {
                    None => true,
                    Some((e, _)) => est < e,
                };
                if better {
                    best = Some((est, i));
                }
            }
            best.map(|(_, i)| i)
        }
    }
}

/// Pop the next request off `board`'s queue per the discipline and put
/// it in service at time `now`, scheduling its completion event.
fn start_next(profiles: &ProfileMatrix, board: &mut BoardState,
              queue: QueueDiscipline, now: f64,
              heap: &mut BinaryHeap<Event>, seq: &mut u64,
              board_idx: usize) {
    let pick = match queue {
        QueueDiscipline::Fifo => 0,
        QueueDiscipline::Priority => {
            // Cheapest (service + switch) first; ties to the earlier
            // arrival (queue order). Queues are short, so the linear
            // scan is cheaper and more deterministic than a heap.
            let mut best = 0usize;
            let mut best_cost = f64::INFINITY;
            for (i, r) in board.queue.iter().enumerate() {
                let c = board
                    .cost_after(profiles, board.loaded, r.model)
                    .expect("queued request must be servable");
                if c < best_cost {
                    best_cost = c;
                    best = i;
                }
            }
            best
        }
    };
    let req = board.queue.remove(pick).expect("queue checked non-empty");
    let p = profiles
        .get(req.model, board.device)
        .expect("queued request must be servable");
    let switch = if board.loaded == req.model {
        0.0
    } else {
        board.switches += 1;
        board.loaded = req.model;
        p.reconfig_ms
    };
    let cost = switch + p.service_ms;
    // Keep the backlog estimator in sync: remove this request's
    // estimated contribution. Priority reordering can make realised
    // switches diverge from the enqueue-time estimates, so an empty
    // queue resets the estimator exactly instead of carrying a
    // residue that would bias SLO-aware dispatch against this board.
    if board.queue.is_empty() {
        board.backlog_ms = 0.0;
        board.tail_model = req.model;
    } else {
        board.backlog_ms = (board.backlog_ms - cost).max(0.0);
    }
    board.busy_ms += cost;
    board.free_at_ms = now + cost;
    board.in_service = Some(req);
    heap.push(Event {
        t_ms: now + cost,
        seq: *seq,
        kind: EventKind::Done(board_idx),
    });
    *seq += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix1(service_ms: f64, reconfig_ms: f64) -> ProfileMatrix {
        let mut m = ProfileMatrix::new(vec!["a".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms, reconfig_ms });
        m
    }

    fn fleet(n: usize) -> FleetCfg {
        FleetCfg {
            boards: (0..n)
                .map(|_| BoardSpec { device: 0, preload: 0 })
                .collect(),
            policy: Policy::LeastLoaded,
            queue: QueueDiscipline::Fifo,
            slo_ms: 100.0,
        }
    }

    #[test]
    fn empty_arrivals_yield_zero_metrics() {
        let m = matrix1(10.0, 5.0);
        let met = simulate_fleet(&m, &fleet(2), &[]);
        assert_eq!(met.completed, 0);
        assert_eq!(met.events, 0);
        assert_eq!(met.p99_ms, 0.0);
        assert_eq!(met.throughput_rps, 0.0);
    }

    #[test]
    fn back_to_back_requests_queue_fifo() {
        // 3 requests at t=0 on one board, 10 ms each: latencies are
        // exactly 10, 20, 30 ms, utilization 1.0.
        let m = matrix1(10.0, 5.0);
        let arr: Vec<Request> = (0..3)
            .map(|id| Request { id, model: 0, arrival_ms: 0.0 })
            .collect();
        let met = simulate_fleet(&m, &fleet(1), &arr);
        assert_eq!(met.completed, 3);
        assert_eq!(met.max_ms, 30.0);
        assert_eq!(met.p50_ms, 20.0);
        assert_eq!(met.makespan_ms, 30.0);
        assert_eq!(met.boards[0].utilization, 1.0);
        assert_eq!(met.switches, 0);
        // 2 events per request: arrival + completion.
        assert_eq!(met.events, 6);
    }

    #[test]
    fn least_loaded_spreads_simultaneous_arrivals() {
        let m = matrix1(10.0, 5.0);
        let arr: Vec<Request> = (0..4)
            .map(|id| Request { id, model: 0, arrival_ms: 0.0 })
            .collect();
        let met = simulate_fleet(&m, &fleet(4), &arr);
        assert_eq!(met.completed, 4);
        assert_eq!(met.max_ms, 10.0, "each board takes one request");
        for b in &met.boards {
            assert_eq!(b.completed, 1);
        }
    }

    #[test]
    fn model_switch_charged_once_until_next_change() {
        // Two models on one board: a→b→b charges one switch, and the
        // b requests after the first pay no reconfiguration.
        let mut m = ProfileMatrix::new(vec!["a".into(), "b".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 10.0, reconfig_ms: 7.0 });
        m.set(1, 0, ServiceProfile { service_ms: 10.0, reconfig_ms: 7.0 });
        let mut cfg = fleet(1);
        cfg.boards[0].preload = 0;
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 1, arrival_ms: 0.0 },
            Request { id: 2, model: 1, arrival_ms: 0.0 },
        ];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.switches, 1);
        // 10 + (7 + 10) + 10 of busy time, ending at t = 37.
        assert_eq!(met.makespan_ms, 37.0);
        assert_eq!(met.max_ms, 37.0);
    }

    #[test]
    fn priority_queue_serves_cheapest_first() {
        // Board busy with a long job; a long and a short job queue up.
        // Priority serves the short one first, FIFO the long one.
        let mut m = ProfileMatrix::new(vec!["long".into(), "short".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 20.0, reconfig_ms: 0.0 });
        m.set(1, 0, ServiceProfile { service_ms: 2.0, reconfig_ms: 0.0 });
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 0, arrival_ms: 1.0 },
            Request { id: 2, model: 1, arrival_ms: 2.0 },
        ];
        let mut cfg = fleet(1);
        cfg.queue = QueueDiscipline::Fifo;
        let fifo = simulate_fleet(&m, &cfg, &arr);
        cfg.queue = QueueDiscipline::Priority;
        let prio = simulate_fleet(&m, &cfg, &arr);
        // FIFO: short waits for both longs (20 + 20 + 2 - 2 = 40 ms).
        // Priority: short runs right after the first long (20 ms).
        assert_eq!(fifo.max_ms, 40.0);
        assert!(prio.mean_ms < fifo.mean_ms,
                "priority {} vs fifo {}", prio.mean_ms, fifo.mean_ms);
        assert_eq!(prio.completed, 3);
    }

    #[test]
    fn slo_aware_keeps_designs_resident() {
        // Two boards preloaded a/b; alternating idle-time arrivals.
        // SLO-aware routes each model to its resident board (0
        // switches); round-robin alternates and pays a switch on
        // every request after the first.
        let mut m = ProfileMatrix::new(vec!["a".into(), "b".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 5.0, reconfig_ms: 50.0 });
        m.set(1, 0, ServiceProfile { service_ms: 5.0, reconfig_ms: 50.0 });
        // a,a,b,b,… — deliberately misaligned with the board rotation
        // so round-robin cannot stay resident by accident.
        let arr: Vec<Request> = (0..8)
            .map(|id| Request {
                id,
                model: (id / 2) % 2,
                arrival_ms: 100.0 * id as f64,
            })
            .collect();
        let mut cfg = FleetCfg {
            boards: vec![BoardSpec { device: 0, preload: 0 },
                         BoardSpec { device: 0, preload: 1 }],
            policy: Policy::SloAware,
            queue: QueueDiscipline::Fifo,
            slo_ms: 100.0,
        };
        let slo = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(slo.switches, 0, "resident designs never reload");
        assert_eq!(slo.p99_ms, 5.0);
        cfg.policy = Policy::RoundRobin;
        let rr = simulate_fleet(&m, &cfg, &arr);
        assert!(rr.switches > 0);
        assert!(slo.switches <= rr.switches);
    }

    #[test]
    fn unservable_requests_are_dropped_and_counted() {
        let mut m = ProfileMatrix::new(vec!["a".into(), "b".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 5.0, reconfig_ms: 1.0 });
        // model "b" has no feasible design anywhere.
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 1, arrival_ms: 1.0 },
        ];
        for policy in [Policy::RoundRobin, Policy::LeastLoaded,
                       Policy::SloAware] {
            let mut cfg = fleet(1);
            cfg.policy = policy;
            let met = simulate_fleet(&m, &cfg, &arr);
            assert_eq!(met.completed, 1, "{policy:?}");
            assert_eq!(met.dropped, 1, "{policy:?}");
        }
    }

    #[test]
    fn policy_and_queue_parse() {
        assert_eq!(Policy::parse("rr"), Some(Policy::RoundRobin));
        assert_eq!(Policy::parse("slo-aware"), Some(Policy::SloAware));
        assert_eq!(Policy::parse("least-loaded"),
                   Some(Policy::LeastLoaded));
        assert!(Policy::parse("nope").is_none());
        assert_eq!(QueueDiscipline::parse("fifo"),
                   Some(QueueDiscipline::Fifo));
        assert_eq!(QueueDiscipline::parse("priority"),
                   Some(QueueDiscipline::Priority));
        assert!(QueueDiscipline::parse("lifo").is_none());
    }
}
