//! Fleet serving — the serving-scale axis on top of the per-design
//! toolflow (ROADMAP north star: heavy HAR traffic, not single clips).
//!
//! HARFLOW3D (§V) optimises one design for one clip's latency; serving
//! millions of users adds the dimensions the throughput-oriented
//! siblings (fpgaHART, FPGA-QHAR) optimise for: queueing, dispatch,
//! and fleet sizing. This module provides
//!
//! * a **deterministic event-driven simulator** over a fleet of FPGA
//!   boards, each serving one loaded design at a time with a per-board
//!   FIFO or priority queue, charging `sim::DesignLatencyProfile`
//!   service latency per clip and the design-switch (reconfiguration)
//!   cost when a board changes design — arrivals come from a seeded
//!   Poisson process ([`arrivals::poisson`]) or a trace file
//!   ([`arrivals::from_trace`]), and every tie is broken by sequence
//!   number so a seed pins the run bit-for-bit;
//! * **clip batching** ([`BatchCfg`]): up to `max_batch` queued clips
//!   of the same model run as one invocation sequence, paying the
//!   pipeline fill once ([`ServiceProfile::batch_ms`]); an idle board
//!   may hold the head clip up to `max_wait_ms` for batchmates;
//! * an **SLO-driven capacity planner** ([`planner::plan`]) that
//!   consumes `report::sweep` design points and searches board counts
//!   × design assignments — homogeneous per device type and, when
//!   enabled, heterogeneous mixed-device compositions — for the
//!   cheapest fleet meeting a p99 SLO at a target arrival rate.

pub mod arrivals;
pub mod cli;
pub mod planner;

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use crate::util::stats::percentile_sorted;

// ------------------------------------------------------------------------
// Profiles: what the simulator charges per request
// ------------------------------------------------------------------------

/// Per (model, device) serving numbers — a lean projection of
/// [`crate::sim::DesignLatencyProfile`] (which carries names and
/// provenance; the inner loop only needs the two latencies).
#[derive(Debug, Clone, Copy)]
pub struct ServiceProfile {
    /// Per-clip service latency (ms) of the optimised design.
    pub service_ms: f64,
    /// Cost (ms) of loading this design onto a board that currently
    /// holds a different one.
    pub reconfig_ms: f64,
    /// Pipeline-fill share of `service_ms` (ms): the one-off
    /// line-buffer priming a batched invocation sequence pays once for
    /// the whole batch instead of once per clip (see
    /// `sim::DesignLatencyProfile::fill_ms`). 0 disables amortisation.
    pub fill_ms: f64,
}

impl ServiceProfile {
    /// Service time (ms) of one invocation sequence carrying `clips`
    /// clips of this design: the first clip pays the full per-clip
    /// latency, every further clip only the fill-free marginal cost.
    /// Exactly `service_ms` for `clips <= 1`, so batch-unaware callers
    /// and `max_batch = 1` fleets are bit-identical to the unbatched
    /// model.
    pub fn batch_ms(&self, clips: usize) -> f64 {
        if clips <= 1 {
            return self.service_ms;
        }
        // Clamp hand-built profiles where fill exceeds service; the
        // simulator-derived profiles satisfy fill < service.
        let marginal = (self.service_ms - self.fill_ms).max(0.0);
        self.service_ms + (clips - 1) as f64 * marginal
    }
}

/// The model × device profile grid the simulator and planner consume.
/// `None` marks an infeasible design point (model does not fit the
/// device); `costs[d]` is the relative board cost of device `d`.
#[derive(Debug, Clone)]
pub struct ProfileMatrix {
    pub models: Vec<String>,
    pub devices: Vec<String>,
    /// Relative board cost per device (see [`planner::board_cost`]).
    pub costs: Vec<f64>,
    grid: Vec<Vec<Option<ServiceProfile>>>,
}

impl ProfileMatrix {
    /// Empty grid (all points infeasible, unit costs).
    pub fn new(models: Vec<String>, devices: Vec<String>)
        -> ProfileMatrix {
        let grid = vec![vec![None; devices.len()]; models.len()];
        let costs = vec![1.0; devices.len()];
        ProfileMatrix { models, devices, costs, grid }
    }

    pub fn set(&mut self, model: usize, device: usize, p: ServiceProfile) {
        self.grid[model][device] = Some(p);
    }

    pub fn get(&self, model: usize, device: usize)
        -> Option<ServiceProfile> {
        self.grid[model][device]
    }

    pub fn model_index(&self, name: &str) -> Option<usize> {
        self.models.iter().position(|m| m == name)
    }

    pub fn device_index(&self, name: &str) -> Option<usize> {
        self.devices.iter().position(|d| d == name)
    }
}

// ------------------------------------------------------------------------
// Requests, boards, policies
// ------------------------------------------------------------------------

/// One inference request: a clip of `model` arriving at `arrival_ms`.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub id: usize,
    /// Row into the [`ProfileMatrix`].
    pub model: usize,
    pub arrival_ms: f64,
}

/// One board of the fleet: a device instance with an initially loaded
/// design (set by the planner / CLI, so a warm fleet pays no switch on
/// its first matching request).
#[derive(Debug, Clone, Copy)]
pub struct BoardSpec {
    /// Column into the [`ProfileMatrix`].
    pub device: usize,
    /// Initially loaded design (model row).
    pub preload: usize,
}

/// Which board a new arrival is queued on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Arrival `i` goes to board `i mod fleet size`.
    RoundRobin,
    /// Fewest requests queued + in service; ties to the lowest index.
    LeastLoaded,
    /// Earliest estimated completion, accounting for the board's
    /// backlog and the design-switch cost a mismatched board would
    /// pay — the policy that keeps designs resident where possible.
    SloAware,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "rr" | "round-robin" => Some(Policy::RoundRobin),
            "ll" | "least-loaded" => Some(Policy::LeastLoaded),
            "slo" | "slo-aware" => Some(Policy::SloAware),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLoaded => "least-loaded",
            Policy::SloAware => "slo-aware",
        }
    }
}

/// Per-board queue discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueDiscipline {
    /// Arrival order.
    Fifo,
    /// Cheapest work first (shortest service + switch on this board;
    /// ties to the earlier arrival) — trades a long clip's tail for
    /// the short clips' percentiles.
    Priority,
}

impl QueueDiscipline {
    pub fn parse(s: &str) -> Option<QueueDiscipline> {
        match s {
            "fifo" => Some(QueueDiscipline::Fifo),
            "priority" | "sjf" => Some(QueueDiscipline::Priority),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            QueueDiscipline::Fifo => "fifo",
            QueueDiscipline::Priority => "priority",
        }
    }
}

/// Clip-batching policy: how many clips one invocation sequence may
/// carry and how long an idle board holds the head clip waiting for
/// batchmates.
#[derive(Debug, Clone, Copy)]
pub struct BatchCfg {
    /// Largest batch (clips per invocation sequence). 1 disables
    /// batching — the simulator is then bit-identical to the
    /// unbatched model.
    pub max_batch: usize,
    /// Longest hold (ms) an *idle* board waits for the candidate batch
    /// to fill before starting short. 0 means purely opportunistic
    /// batching: only clips already queued when service starts are
    /// grouped, and no hold events exist.
    pub max_wait_ms: f64,
}

impl BatchCfg {
    pub fn new(max_batch: usize, max_wait_ms: f64) -> BatchCfg {
        BatchCfg { max_batch: max_batch.max(1), max_wait_ms }
    }

    /// Whether holds can occur (batch > 1 and a positive window).
    fn holds(&self) -> bool {
        self.max_batch > 1 && self.max_wait_ms > 0.0
    }
}

impl Default for BatchCfg {
    /// Batching off: one clip per invocation sequence, no hold.
    fn default() -> Self {
        BatchCfg { max_batch: 1, max_wait_ms: 0.0 }
    }
}

/// Fleet composition + serving policy for one simulation run.
#[derive(Debug, Clone)]
pub struct FleetCfg {
    pub boards: Vec<BoardSpec>,
    pub policy: Policy,
    pub queue: QueueDiscipline,
    /// The latency objective (ms); violations are counted per request.
    pub slo_ms: f64,
    /// Clip batching (default: off).
    pub batch: BatchCfg,
}

// ------------------------------------------------------------------------
// Metrics
// ------------------------------------------------------------------------

/// Per-board outcome of a simulation run.
#[derive(Debug, Clone)]
pub struct BoardReport {
    pub device: usize,
    pub completed: usize,
    /// Invocation sequences started (== completed when batching off).
    pub batches: usize,
    pub switches: usize,
    pub busy_ms: f64,
    /// busy time / makespan.
    pub utilization: f64,
}

/// Fleet-level outcome of a simulation run. All fields are
/// deterministic functions of (profiles, cfg, arrivals) — no wall
/// clock anywhere — so a fixed seed reproduces them bit-for-bit.
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    pub completed: usize,
    /// Requests no board could serve (their model fits no board's
    /// device) — always 0 for planner-built fleets.
    pub dropped: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// Completed requests per second of simulated time.
    pub throughput_rps: f64,
    /// Last completion time (simulated ms; arrivals start near 0).
    pub makespan_ms: f64,
    pub slo_ms: f64,
    pub slo_violations: usize,
    pub switches: usize,
    /// Invocation sequences started across the fleet. Equals
    /// `completed` when batching is off; under batching,
    /// `completed / batches` is the realised mean batch size.
    pub batches: usize,
    /// Simulator events processed (arrivals + completions + expired
    /// batch holds) — the bench's events/sec numerator.
    pub events: usize,
    pub boards: Vec<BoardReport>,
}

impl FleetMetrics {
    pub fn mean_utilization(&self) -> f64 {
        if self.boards.is_empty() {
            return 0.0;
        }
        self.boards.iter().map(|b| b.utilization).sum::<f64>()
            / self.boards.len() as f64
    }

    pub fn slo_met(&self) -> bool {
        self.p99_ms <= self.slo_ms
    }

    /// Realised mean clips per invocation sequence (1.0 for an empty
    /// run, so reports divide safely).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            1.0
        } else {
            self.completed as f64 / self.batches as f64
        }
    }
}

// ------------------------------------------------------------------------
// Event-driven simulator
// ------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum EventKind {
    /// Index into the arrivals slice.
    Arrival(usize),
    /// Board finished its in-service invocation sequence.
    Done(usize),
    /// A batch hold expired on board `.0`; `.1` is the hold epoch the
    /// event was armed for (stale epochs are ignored — the board
    /// started or re-held in the meantime).
    HoldExpired(usize, u64),
}

/// Heap event. Ordered so `BinaryHeap::pop` yields the *earliest*
/// time; equal times break by insertion sequence, which makes the
/// event order — and therefore the whole run — independent of float
/// coincidences and fully deterministic.
#[derive(Debug, Clone, Copy)]
struct Event {
    t_ms: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for Event {
    fn cmp(&self, o: &Self) -> Ordering {
        // Reversed: the max-heap pops the minimum (time, seq).
        o.t_ms.total_cmp(&self.t_ms).then_with(|| o.seq.cmp(&self.seq))
    }
}

/// Live board state during a run.
struct BoardState {
    device: usize,
    /// Currently loaded design (model row).
    loaded: usize,
    /// Design loaded once the whole queue has drained — the backlog
    /// estimator's switch-cost anchor.
    tail_model: usize,
    queue: VecDeque<Request>,
    /// Clips of the in-flight invocation sequence (empty = idle).
    in_service: Vec<Request>,
    free_at_ms: f64,
    /// Estimated queued work (service + expected switches), ms.
    backlog_ms: f64,
    busy_ms: f64,
    completed: usize,
    switches: usize,
    batches: usize,
    /// An idle board waiting out a batch hold window.
    holding: bool,
    /// Bumped every time a hold is armed; a `HoldExpired` event only
    /// acts when its epoch still matches (invalidates stale timers).
    hold_epoch: u64,
}

impl BoardState {
    /// Estimated cost of serving one clip of `model` right after
    /// `prev` on this board. Batch-aware: when batching is on and the
    /// clip joins the same design's tail, it can ride an invocation
    /// sequence and pays only the fill-free marginal cost; otherwise
    /// it pays full service plus the switch if mismatched.
    fn cost_after(&self, profiles: &ProfileMatrix, prev: usize,
                  model: usize, batch: &BatchCfg) -> Option<f64> {
        let p = profiles.get(model, self.device)?;
        if prev == model {
            if batch.max_batch > 1 {
                return Some(p.batch_ms(2) - p.batch_ms(1));
            }
            return Some(p.service_ms);
        }
        Some(p.service_ms + p.reconfig_ms)
    }
}

/// Run the fleet through a sorted arrival stream. Panics if `arrivals`
/// is not sorted by `arrival_ms` (the arrival constructors guarantee
/// it) or the fleet is empty.
pub fn simulate_fleet(profiles: &ProfileMatrix, cfg: &FleetCfg,
                      arrivals: &[Request]) -> FleetMetrics {
    assert!(!cfg.boards.is_empty(), "fleet has no boards");
    debug_assert!(arrivals.windows(2)
                      .all(|w| w[0].arrival_ms <= w[1].arrival_ms),
                  "arrivals must be time-sorted");

    let mut boards: Vec<BoardState> = cfg
        .boards
        .iter()
        .map(|b| BoardState {
            device: b.device,
            loaded: b.preload,
            tail_model: b.preload,
            queue: VecDeque::new(),
            in_service: Vec::new(),
            free_at_ms: 0.0,
            backlog_ms: 0.0,
            busy_ms: 0.0,
            completed: 0,
            switches: 0,
            batches: 0,
            holding: false,
            hold_epoch: 0,
        })
        .collect();

    let mut heap: BinaryHeap<Event> = BinaryHeap::with_capacity(
        arrivals.len() + boards.len());
    let mut seq = 0u64;
    for (i, r) in arrivals.iter().enumerate() {
        heap.push(Event { t_ms: r.arrival_ms, seq, kind: EventKind::Arrival(i) });
        seq += 1;
    }

    let mut latencies: Vec<f64> = Vec::with_capacity(arrivals.len());
    let mut dropped = 0usize;
    let mut events = 0usize;
    let mut rr_next = 0usize;
    let mut makespan_ms = 0.0f64;

    while let Some(ev) = heap.pop() {
        events += 1;
        let now = ev.t_ms;
        match ev.kind {
            EventKind::Arrival(i) => {
                let req = arrivals[i];
                let Some(b) = dispatch(profiles, &boards, cfg.policy,
                                       &mut rr_next, &req, now,
                                       &cfg.batch)
                else {
                    dropped += 1;
                    continue;
                };
                let board = &mut boards[b];
                let est = board
                    .cost_after(profiles, board.tail_model, req.model,
                                &cfg.batch)
                    .expect("dispatch returned a capable board");
                board.backlog_ms += est;
                board.tail_model = req.model;
                board.queue.push_back(req);
                if board.in_service.is_empty() {
                    maybe_start(profiles, board, cfg, now, &mut heap,
                                &mut seq, b);
                }
            }
            EventKind::Done(b) => {
                let board = &mut boards[b];
                let batch = std::mem::take(&mut board.in_service);
                assert!(!batch.is_empty(),
                        "completion without in-service request");
                board.completed += batch.len();
                for req in &batch {
                    latencies.push(now - req.arrival_ms);
                }
                makespan_ms = makespan_ms.max(now);
                if !board.queue.is_empty() {
                    maybe_start(profiles, board, cfg, now, &mut heap,
                                &mut seq, b);
                }
            }
            EventKind::HoldExpired(b, epoch) => {
                let board = &mut boards[b];
                if board.holding && board.hold_epoch == epoch
                    && board.in_service.is_empty()
                    && !board.queue.is_empty()
                {
                    board.holding = false;
                    start_next(profiles, board, cfg, now, &mut heap,
                               &mut seq, b);
                }
            }
        }
    }

    let slo_violations =
        latencies.iter().filter(|&&l| l > cfg.slo_ms).count();
    let mean_ms = crate::util::stats::mean(&latencies);
    // One sort serves every percentile and the max (metrics are on the
    // benched path — events/sec should measure the simulator, not
    // repeated bookkeeping sorts).
    let mut sorted = latencies;
    sorted.sort_by(|a, b| a.total_cmp(b));
    let board_reports: Vec<BoardReport> = boards
        .iter()
        .map(|b| BoardReport {
            device: b.device,
            completed: b.completed,
            batches: b.batches,
            switches: b.switches,
            busy_ms: b.busy_ms,
            utilization: if makespan_ms > 0.0 {
                b.busy_ms / makespan_ms
            } else {
                0.0
            },
        })
        .collect();
    FleetMetrics {
        completed: sorted.len(),
        dropped,
        p50_ms: percentile_sorted(&sorted, 50.0),
        p95_ms: percentile_sorted(&sorted, 95.0),
        p99_ms: percentile_sorted(&sorted, 99.0),
        mean_ms,
        max_ms: sorted.last().copied().unwrap_or(0.0),
        throughput_rps: if makespan_ms > 0.0 {
            sorted.len() as f64 / (makespan_ms / 1e3)
        } else {
            0.0
        },
        makespan_ms,
        slo_ms: cfg.slo_ms,
        slo_violations,
        switches: boards.iter().map(|b| b.switches).sum(),
        batches: boards.iter().map(|b| b.batches).sum(),
        events,
        boards: board_reports,
    }
}

/// Choose a board for `req` under `policy`. Boards whose device has no
/// feasible design for the request's model are skipped; `None` means
/// no board can serve it (the request is dropped and counted).
fn dispatch(profiles: &ProfileMatrix, boards: &[BoardState],
            policy: Policy, rr_next: &mut usize, req: &Request,
            now: f64, batch: &BatchCfg) -> Option<usize> {
    let capable =
        |b: &BoardState| profiles.get(req.model, b.device).is_some();
    match policy {
        Policy::RoundRobin => {
            // Advance the cursor past incapable boards (bounded by the
            // fleet size); the cursor moves exactly one capable board
            // per arrival, so the rotation stays fair.
            for _ in 0..boards.len() {
                let b = *rr_next % boards.len();
                *rr_next = (*rr_next + 1) % boards.len();
                if capable(&boards[b]) {
                    return Some(b);
                }
            }
            None
        }
        // Load is measured in clips (queued + in flight), so a board
        // running a full batch reads as busier than one running a
        // single clip — the batch-aware load signal.
        Policy::LeastLoaded => boards
            .iter()
            .enumerate()
            .filter(|(_, b)| capable(b))
            .min_by_key(|(i, b)| {
                (b.queue.len() + b.in_service.len(), *i)
            })
            .map(|(i, _)| i),
        Policy::SloAware => {
            // Earliest estimated completion of this request: current
            // service tail + queued backlog + its own cost, which is
            // batch-aware (a clip joining its design's resident tail
            // pays only the marginal batched cost — see
            // `BoardState::cost_after`). The backlog term is an
            // estimate under priority reordering, exact under FIFO.
            let mut best: Option<(f64, usize)> = None;
            for (i, b) in boards.iter().enumerate() {
                let Some(own) =
                    b.cost_after(profiles, b.tail_model, req.model,
                                 batch)
                else {
                    continue;
                };
                let start = if b.in_service.is_empty() {
                    now
                } else {
                    b.free_at_ms.max(now)
                };
                let est = start + b.backlog_ms + own;
                let better = match best {
                    None => true,
                    Some((e, _)) => est < e,
                };
                if better {
                    best = Some((est, i));
                }
            }
            best.map(|(_, i)| i)
        }
    }
}

/// Index into `board.queue` of the request the discipline serves next.
fn pick_index(profiles: &ProfileMatrix, board: &BoardState,
              queue: QueueDiscipline, batch: &BatchCfg) -> usize {
    match queue {
        QueueDiscipline::Fifo => 0,
        QueueDiscipline::Priority => {
            // Cheapest (service + switch) first; ties to the earlier
            // arrival (queue order). Queues are short, so the linear
            // scan is cheaper and more deterministic than a heap.
            let mut best = 0usize;
            let mut best_cost = f64::INFINITY;
            for (i, r) in board.queue.iter().enumerate() {
                let c = board
                    .cost_after(profiles, board.loaded, r.model, batch)
                    .expect("queued request must be servable");
                if c < best_cost {
                    best_cost = c;
                    best = i;
                }
            }
            best
        }
    }
}

/// Clips the next invocation sequence would carry if started now: the
/// discipline's pick plus every queued clip of the same model, capped
/// at `max_batch`. Only consulted while deciding whether to hold.
fn candidate_batch_len(profiles: &ProfileMatrix, board: &BoardState,
                       queue: QueueDiscipline, batch: &BatchCfg)
    -> usize {
    let pick = pick_index(profiles, board, queue, batch);
    let model = board.queue[pick].model;
    board
        .queue
        .iter()
        .filter(|r| r.model == model)
        .take(batch.max_batch)
        .count()
}

/// Start the board's next invocation sequence — or, when batching with
/// a hold window is on and the candidate batch is still short, arm a
/// hold timer and wait for batchmates. Requires a non-empty queue and
/// an idle board.
fn maybe_start(profiles: &ProfileMatrix, board: &mut BoardState,
               cfg: &FleetCfg, now: f64, heap: &mut BinaryHeap<Event>,
               seq: &mut u64, board_idx: usize) {
    let full = !cfg.batch.holds()
        || candidate_batch_len(profiles, board, cfg.queue, &cfg.batch)
            >= cfg.batch.max_batch;
    if full {
        board.holding = false;
        start_next(profiles, board, cfg, now, heap, seq, board_idx);
    } else if !board.holding {
        board.holding = true;
        board.hold_epoch += 1;
        heap.push(Event {
            t_ms: now + cfg.batch.max_wait_ms,
            seq: *seq,
            kind: EventKind::HoldExpired(board_idx, board.hold_epoch),
        });
        *seq += 1;
    }
    // Already holding with a still-short batch: keep waiting; the
    // armed timer (or a filling arrival) will start the sequence.
}

/// Pop the next invocation sequence off `board`'s queue — the
/// discipline's pick plus (under batching) every queued clip of the
/// same model up to `max_batch`, in arrival order — and put it in
/// service at time `now`, scheduling its completion event.
fn start_next(profiles: &ProfileMatrix, board: &mut BoardState,
              cfg: &FleetCfg, now: f64, heap: &mut BinaryHeap<Event>,
              seq: &mut u64, board_idx: usize) {
    let pick = pick_index(profiles, board, cfg.queue, &cfg.batch);
    let first = board.queue.remove(pick).expect("queue checked non-empty");
    let model = first.model;
    let mut batch = vec![first];
    if cfg.batch.max_batch > 1 {
        let mut i = 0;
        while batch.len() < cfg.batch.max_batch && i < board.queue.len()
        {
            if board.queue[i].model == model {
                batch.push(board.queue.remove(i).expect("index in range"));
            } else {
                i += 1;
            }
        }
    }
    let p = profiles
        .get(model, board.device)
        .expect("queued request must be servable");
    let switch = if board.loaded == model {
        0.0
    } else {
        board.switches += 1;
        board.loaded = model;
        p.reconfig_ms
    };
    let cost = switch + p.batch_ms(batch.len());
    // Keep the backlog estimator in sync: remove this sequence's
    // estimated contribution. Priority reordering and batch
    // amortisation can make realised costs diverge from the
    // enqueue-time estimates, so an empty queue resets the estimator
    // exactly instead of carrying a residue that would bias SLO-aware
    // dispatch against this board.
    if board.queue.is_empty() {
        board.backlog_ms = 0.0;
        board.tail_model = model;
    } else {
        board.backlog_ms = (board.backlog_ms - cost).max(0.0);
    }
    board.busy_ms += cost;
    board.free_at_ms = now + cost;
    board.in_service = batch;
    board.batches += 1;
    heap.push(Event {
        t_ms: now + cost,
        seq: *seq,
        kind: EventKind::Done(board_idx),
    });
    *seq += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix1(service_ms: f64, reconfig_ms: f64) -> ProfileMatrix {
        let mut m = ProfileMatrix::new(vec!["a".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms, reconfig_ms,
                                     fill_ms: 0.0 });
        m
    }

    fn fleet(n: usize) -> FleetCfg {
        FleetCfg {
            boards: (0..n)
                .map(|_| BoardSpec { device: 0, preload: 0 })
                .collect(),
            policy: Policy::LeastLoaded,
            queue: QueueDiscipline::Fifo,
            slo_ms: 100.0,
            batch: BatchCfg::default(),
        }
    }

    #[test]
    fn empty_arrivals_yield_zero_metrics() {
        let m = matrix1(10.0, 5.0);
        let met = simulate_fleet(&m, &fleet(2), &[]);
        assert_eq!(met.completed, 0);
        assert_eq!(met.events, 0);
        assert_eq!(met.p99_ms, 0.0);
        assert_eq!(met.throughput_rps, 0.0);
    }

    #[test]
    fn back_to_back_requests_queue_fifo() {
        // 3 requests at t=0 on one board, 10 ms each: latencies are
        // exactly 10, 20, 30 ms, utilization 1.0.
        let m = matrix1(10.0, 5.0);
        let arr: Vec<Request> = (0..3)
            .map(|id| Request { id, model: 0, arrival_ms: 0.0 })
            .collect();
        let met = simulate_fleet(&m, &fleet(1), &arr);
        assert_eq!(met.completed, 3);
        assert_eq!(met.max_ms, 30.0);
        assert_eq!(met.p50_ms, 20.0);
        assert_eq!(met.makespan_ms, 30.0);
        assert_eq!(met.boards[0].utilization, 1.0);
        assert_eq!(met.switches, 0);
        // 2 events per request: arrival + completion.
        assert_eq!(met.events, 6);
    }

    #[test]
    fn least_loaded_spreads_simultaneous_arrivals() {
        let m = matrix1(10.0, 5.0);
        let arr: Vec<Request> = (0..4)
            .map(|id| Request { id, model: 0, arrival_ms: 0.0 })
            .collect();
        let met = simulate_fleet(&m, &fleet(4), &arr);
        assert_eq!(met.completed, 4);
        assert_eq!(met.max_ms, 10.0, "each board takes one request");
        for b in &met.boards {
            assert_eq!(b.completed, 1);
        }
    }

    #[test]
    fn model_switch_charged_once_until_next_change() {
        // Two models on one board: a→b→b charges one switch, and the
        // b requests after the first pay no reconfiguration.
        let mut m = ProfileMatrix::new(vec!["a".into(), "b".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 10.0, reconfig_ms: 7.0, fill_ms: 0.0 });
        m.set(1, 0, ServiceProfile { service_ms: 10.0, reconfig_ms: 7.0, fill_ms: 0.0 });
        let mut cfg = fleet(1);
        cfg.boards[0].preload = 0;
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 1, arrival_ms: 0.0 },
            Request { id: 2, model: 1, arrival_ms: 0.0 },
        ];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.switches, 1);
        // 10 + (7 + 10) + 10 of busy time, ending at t = 37.
        assert_eq!(met.makespan_ms, 37.0);
        assert_eq!(met.max_ms, 37.0);
    }

    #[test]
    fn priority_queue_serves_cheapest_first() {
        // Board busy with a long job; a long and a short job queue up.
        // Priority serves the short one first, FIFO the long one.
        let mut m = ProfileMatrix::new(vec!["long".into(), "short".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 20.0, reconfig_ms: 0.0, fill_ms: 0.0 });
        m.set(1, 0, ServiceProfile { service_ms: 2.0, reconfig_ms: 0.0, fill_ms: 0.0 });
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 0, arrival_ms: 1.0 },
            Request { id: 2, model: 1, arrival_ms: 2.0 },
        ];
        let mut cfg = fleet(1);
        cfg.queue = QueueDiscipline::Fifo;
        let fifo = simulate_fleet(&m, &cfg, &arr);
        cfg.queue = QueueDiscipline::Priority;
        let prio = simulate_fleet(&m, &cfg, &arr);
        // FIFO: short waits for both longs (20 + 20 + 2 - 2 = 40 ms).
        // Priority: short runs right after the first long (20 ms).
        assert_eq!(fifo.max_ms, 40.0);
        assert!(prio.mean_ms < fifo.mean_ms,
                "priority {} vs fifo {}", prio.mean_ms, fifo.mean_ms);
        assert_eq!(prio.completed, 3);
    }

    #[test]
    fn slo_aware_keeps_designs_resident() {
        // Two boards preloaded a/b; alternating idle-time arrivals.
        // SLO-aware routes each model to its resident board (0
        // switches); round-robin alternates and pays a switch on
        // every request after the first.
        let mut m = ProfileMatrix::new(vec!["a".into(), "b".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 5.0, reconfig_ms: 50.0, fill_ms: 0.0 });
        m.set(1, 0, ServiceProfile { service_ms: 5.0, reconfig_ms: 50.0, fill_ms: 0.0 });
        // a,a,b,b,… — deliberately misaligned with the board rotation
        // so round-robin cannot stay resident by accident.
        let arr: Vec<Request> = (0..8)
            .map(|id| Request {
                id,
                model: (id / 2) % 2,
                arrival_ms: 100.0 * id as f64,
            })
            .collect();
        let mut cfg = FleetCfg {
            boards: vec![BoardSpec { device: 0, preload: 0 },
                         BoardSpec { device: 0, preload: 1 }],
            policy: Policy::SloAware,
            queue: QueueDiscipline::Fifo,
            slo_ms: 100.0,
            batch: BatchCfg::default(),
        };
        let slo = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(slo.switches, 0, "resident designs never reload");
        assert_eq!(slo.p99_ms, 5.0);
        cfg.policy = Policy::RoundRobin;
        let rr = simulate_fleet(&m, &cfg, &arr);
        assert!(rr.switches > 0);
        assert!(slo.switches <= rr.switches);
    }

    #[test]
    fn unservable_requests_are_dropped_and_counted() {
        let mut m = ProfileMatrix::new(vec!["a".into(), "b".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms: 5.0, reconfig_ms: 1.0, fill_ms: 0.0 });
        // model "b" has no feasible design anywhere.
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 1, arrival_ms: 1.0 },
        ];
        for policy in [Policy::RoundRobin, Policy::LeastLoaded,
                       Policy::SloAware] {
            let mut cfg = fleet(1);
            cfg.policy = policy;
            let met = simulate_fleet(&m, &cfg, &arr);
            assert_eq!(met.completed, 1, "{policy:?}");
            assert_eq!(met.dropped, 1, "{policy:?}");
        }
    }

    fn matrix_fill(service_ms: f64, fill_ms: f64) -> ProfileMatrix {
        let mut m = ProfileMatrix::new(vec!["a".into()],
                                       vec!["dev".into()]);
        m.set(0, 0, ServiceProfile { service_ms, reconfig_ms: 5.0,
                                     fill_ms });
        m
    }

    #[test]
    fn batch_ms_amortises_fill() {
        let p = ServiceProfile { service_ms: 10.0, reconfig_ms: 5.0,
                                 fill_ms: 4.0 };
        assert_eq!(p.batch_ms(0), 10.0);
        assert_eq!(p.batch_ms(1), 10.0);
        assert_eq!(p.batch_ms(2), 16.0, "10 + one 6 ms marginal clip");
        assert_eq!(p.batch_ms(4), 28.0, "10 + three 6 ms marginal clips");
        // fill >= service clamps the marginal cost at zero.
        let degenerate = ServiceProfile { service_ms: 3.0,
                                          reconfig_ms: 0.0,
                                          fill_ms: 9.0 };
        assert_eq!(degenerate.batch_ms(5), 3.0);
    }

    #[test]
    fn opportunistic_batching_groups_queued_clips() {
        // 3 clips at t=0 on one board, service 10 / fill 4, batch cap
        // 4, no hold window. The first clip starts alone (nothing else
        // queued yet at its event); the two clips queued behind it run
        // as one sequence: 10 + (10 + 6) = 26 ms makespan vs 30 ms
        // unbatched.
        let m = matrix_fill(10.0, 4.0);
        let mut cfg = fleet(1);
        cfg.batch = BatchCfg::new(4, 0.0);
        let arr: Vec<Request> = (0..3)
            .map(|id| Request { id, model: 0, arrival_ms: 0.0 })
            .collect();
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 3);
        assert_eq!(met.batches, 2, "1-clip + 2-clip sequences");
        assert_eq!(met.makespan_ms, 26.0);
        assert_eq!(met.max_ms, 26.0);
        // 3 arrivals + 2 completions, no hold events.
        assert_eq!(met.events, 5);
        let unbatched = simulate_fleet(&m, &fleet(1), &arr);
        assert_eq!(unbatched.makespan_ms, 30.0);
        assert_eq!(unbatched.batches, 3);
    }

    #[test]
    fn hold_window_fills_batch_from_later_arrival() {
        // Batch cap 2 with a 5 ms hold: the t=0 clip waits, the t=2
        // clip fills the batch, and the pair starts immediately at
        // t=2 (cost 16 ms -> done at 18). The stale hold timer at t=5
        // is a counted no-op event.
        let m = matrix_fill(10.0, 4.0);
        let mut cfg = fleet(1);
        cfg.batch = BatchCfg::new(2, 5.0);
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 0, arrival_ms: 2.0 },
        ];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 2);
        assert_eq!(met.batches, 1, "one 2-clip sequence");
        assert_eq!(met.makespan_ms, 18.0);
        assert_eq!(met.max_ms, 18.0, "head clip: 2 ms hold + 16 ms");
        assert_eq!(met.mean_ms, 17.0, "(18 + 16) / 2");
        // 2 arrivals + 1 expired (stale) hold + 1 completion.
        assert_eq!(met.events, 4);
    }

    #[test]
    fn hold_expiry_starts_short_batch() {
        // A lone clip under a 4-wide batch cap with a 5 ms hold: no
        // batchmates ever arrive, the timer expires, and the clip runs
        // alone having paid the full hold window.
        let m = matrix_fill(10.0, 4.0);
        let mut cfg = fleet(1);
        cfg.batch = BatchCfg::new(4, 5.0);
        let arr = vec![Request { id: 0, model: 0, arrival_ms: 0.0 }];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 1);
        assert_eq!(met.batches, 1);
        assert_eq!(met.max_ms, 15.0, "5 ms hold + 10 ms service");
        assert_eq!(met.events, 3);
    }

    #[test]
    fn batches_never_mix_models() {
        // a, b, a queued: the b sequence must not absorb the trailing
        // a clip, so three sequences run and two switches are paid.
        let mut m = ProfileMatrix::new(vec!["a".into(), "b".into()],
                                       vec!["dev".into()]);
        for i in 0..2 {
            m.set(i, 0, ServiceProfile { service_ms: 10.0,
                                         reconfig_ms: 7.0,
                                         fill_ms: 4.0 });
        }
        let mut cfg = fleet(1);
        cfg.batch = BatchCfg::new(4, 0.0);
        let arr = vec![
            Request { id: 0, model: 0, arrival_ms: 0.0 },
            Request { id: 1, model: 1, arrival_ms: 0.0 },
            Request { id: 2, model: 0, arrival_ms: 0.0 },
        ];
        let met = simulate_fleet(&m, &cfg, &arr);
        assert_eq!(met.completed, 3);
        assert_eq!(met.batches, 3);
        assert_eq!(met.switches, 2, "b loads, then a reloads");
        // 10 + (7 + 10) + (7 + 10) of busy time.
        assert_eq!(met.makespan_ms, 44.0);
    }

    #[test]
    fn policy_and_queue_parse() {
        assert_eq!(Policy::parse("rr"), Some(Policy::RoundRobin));
        assert_eq!(Policy::parse("slo-aware"), Some(Policy::SloAware));
        assert_eq!(Policy::parse("least-loaded"),
                   Some(Policy::LeastLoaded));
        assert!(Policy::parse("nope").is_none());
        assert_eq!(QueueDiscipline::parse("fifo"),
                   Some(QueueDiscipline::Fifo));
        assert_eq!(QueueDiscipline::parse("priority"),
                   Some(QueueDiscipline::Priority));
        assert!(QueueDiscipline::parse("lifo").is_none());
    }
}
