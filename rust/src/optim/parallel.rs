//! Parallel multi-chain DSE engine: K independent simulated-annealing
//! [`Chain`]s on `std::thread`, with periodic best-so-far exchange.
//!
//! The paper's practical bottleneck (shared with fpgaHART and FMM-X3D)
//! is DSE wall-time across (model, device) pairs; a single chain is
//! already zero-clone and incremental, so the remaining lever is
//! running many chains concurrently. Each chain owns its complete
//! mutable state (design, resource cache, latency memo, reverse index,
//! RNG) — see [`Chain`] — so chains share nothing and scale across
//! cores.
//!
//! Determinism contract:
//! * chain `i` anneals on RNG stream `i` of the configured seed
//!   (`util::rng::stream_seed`; stream 0 *is* the seed);
//! * chains synchronise at fixed temperature-step barriers, and the
//!   exchange applied at a barrier depends only on chain states —
//!   never on thread scheduling;
//! * therefore a K-chain run is reproducible bit-for-bit, and a
//!   1-chain run (no exchanges) is bit-identical to the sequential
//!   `Optimizer::run` (pinned by `rust/tests/parallel.rs`).

use crate::device::Device;
use crate::model::ModelGraph;
use crate::resource::ResourceModel;

use super::{Chain, OptCfg, OptResult, Optimizer};

/// Multi-chain engine configuration.
#[derive(Debug, Clone)]
pub struct ParCfg {
    /// Number of concurrent SA chains (1 = sequential engine).
    pub chains: usize,
    /// Temperature steps each chain runs between exchange barriers.
    pub exchange_every: usize,
}

impl Default for ParCfg {
    fn default() -> Self {
        ParCfg { chains: 4, exchange_every: 32 }
    }
}

/// Deterministic best-so-far exchange at a barrier: the globally best
/// chain (lowest best latency, ties to the lowest chain index) donates
/// its best design to every chain whose *current* design is worse.
fn exchange(chains: &mut [Chain]) {
    let Some(donor) = chains
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.best_latency().total_cmp(&b.1.best_latency()))
        .map(|(i, _)| i)
    else {
        return;
    };
    let best = chains[donor].best_design().clone();
    let best_lat = chains[donor].best_latency();
    for (i, chain) in chains.iter_mut().enumerate() {
        if i != donor && best_lat < chain.current_latency() {
            chain.adopt(&best, best_lat);
        }
    }
}

/// Merge finished chains into one [`OptResult`]: the best chain's
/// design and latency, a globally monotone best-so-far history, the
/// union of the pareto clouds, and aggregate iteration counts (the
/// multi-chain `states_per_sec` numerator).
fn merge(results: Vec<OptResult>) -> OptResult {
    // `results` is never empty (k >= 2 on this path); the fallback
    // index keeps this total without a panic path.
    let best_idx = results
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.latency_cycles.total_cmp(&b.1.latency_cycles))
        .map(|(i, _)| i)
        .unwrap_or(0);

    let mut events: Vec<(usize, f64)> = Vec::new();
    let mut accepted = Vec::new();
    let mut iterations = 0usize;
    let mut accepted_moves = 0usize;
    for r in &results {
        events.extend_from_slice(&r.history);
        accepted.extend_from_slice(&r.accepted);
        iterations += r.iterations;
        accepted_moves += r.accepted_moves;
    }
    // Global best-so-far trace: sort by iteration (largest latency
    // first within a tie so the running minimum keeps the best), then
    // keep strictly improving points. Fully deterministic.
    events.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.total_cmp(&a.1)));
    let mut history = Vec::new();
    let mut best_ms = f64::INFINITY;
    for (it, ms) in events {
        if ms < best_ms {
            best_ms = ms;
            history.push((it, ms));
        }
    }

    let best = &results[best_idx];
    OptResult {
        design: best.design.clone(),
        latency_cycles: best.latency_cycles,
        latency_ms: best.latency_ms,
        resources: best.resources,
        history,
        accepted,
        iterations,
        accepted_moves,
    }
}

/// Optimise `model` for `device` with `par.chains` concurrent SA
/// chains. One chain degenerates to the sequential engine
/// (bit-identical results); K chains run on K `std::thread`s,
/// exchanging best designs every `par.exchange_every` temperature
/// steps, and return the merged result.
pub fn optimize_parallel(model: &ModelGraph, device: &Device,
                         rm: &ResourceModel, cfg: OptCfg, par: &ParCfg)
    -> Result<OptResult, String> {
    optimize_parallel_obs(model, device, rm, cfg, par, false, false)
        .map(|(r, _)| r)
}

/// [`optimize_parallel`] with observability hooks: when `telemetry`
/// is set, every chain records SA convergence telemetry (returned in
/// chain order); when `progress` is set, one line per exchange barrier
/// goes to stderr (stdout byte-pins are unaffected). Both off
/// reproduces [`optimize_parallel`] exactly — recording draws no RNG
/// and the barrier/exchange schedule is untouched (pinned by
/// `rust/tests/obs.rs`).
pub fn optimize_parallel_obs(model: &ModelGraph, device: &Device,
                             rm: &ResourceModel, cfg: OptCfg,
                             par: &ParCfg, telemetry: bool,
                             progress: bool)
    -> Result<(OptResult, Vec<crate::obs::SaTelemetry>), String> {
    let k = par.chains.max(1);
    let opt = Optimizer::new(model, device, rm, cfg);
    if k == 1 {
        // One chain IS the sequential engine — delegating makes the
        // bit-identity contract true by construction.
        let mut chain = Chain::new(&opt, 0)?;
        if telemetry {
            chain.enable_telemetry(0);
        }
        while !chain.done() {
            chain.step_temp();
        }
        let tels: Vec<_> = chain.take_telemetry().into_iter().collect();
        let r = chain.finish();
        r.design.validate(model).map_err(|e| {
            format!("optimizer produced an invalid design: {e}")
        })?;
        return Ok((r, tels));
    }
    let mut chains = (0..k as u64)
        .map(|i| Chain::new(&opt, i))
        .collect::<Result<Vec<_>, _>>()?;
    if telemetry {
        for (i, chain) in chains.iter_mut().enumerate() {
            chain.enable_telemetry(i as u64);
        }
    }

    let rounds = par.exchange_every.max(1);
    let mut barrier = 0usize;
    while chains.iter().any(|c| !c.done()) {
        std::thread::scope(|scope| {
            for chain in chains.iter_mut() {
                scope.spawn(move || {
                    for _ in 0..rounds {
                        if chain.done() {
                            break;
                        }
                        chain.step_temp();
                    }
                });
            }
        });
        barrier += 1;
        if progress {
            let best = chains
                .iter()
                .map(Chain::best_latency)
                .fold(f64::INFINITY, f64::min);
            eprintln!(
                "[optimize] barrier {barrier}: {k} chains, best \
                 {best:.0} cycles");
        }
        // Exchanging after the final round would be wasted work:
        // chains share one temperature schedule, so they all finish
        // together, and merge() already selects the global best.
        if chains.iter().any(|c| !c.done()) {
            exchange(&mut chains);
        }
    }

    let tels: Vec<_> = chains
        .iter_mut()
        .filter_map(Chain::take_telemetry)
        .collect();
    let r = merge(chains.into_iter().map(Chain::finish).collect());
    // Same result-level §V-B validation the sequential engine runs —
    // the merged best came from a chain, but verify after compaction.
    r.design.validate(model).map_err(|e| {
        format!("optimizer produced an invalid design: {e}")
    })?;
    Ok((r, tels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device;
    use crate::model::zoo;
    use crate::optim;

    #[test]
    fn one_chain_matches_sequential_bitwise() {
        let m = zoo::c3d_tiny();
        let dev = device::by_name("zcu102").unwrap();
        let rm = ResourceModel::fit(1, 120);
        let cfg = OptCfg::fast(7);
        let seq = optim::optimize(&m, &dev, &rm, cfg.clone()).unwrap();
        let par = optimize_parallel(&m, &dev, &rm, cfg,
                                    &ParCfg { chains: 1,
                                              exchange_every: 4 })
            .unwrap();
        assert_eq!(seq.latency_cycles.to_bits(),
                   par.latency_cycles.to_bits());
        assert_eq!(seq.iterations, par.iterations);
        assert_eq!(seq.accepted_moves, par.accepted_moves);
    }

    #[test]
    fn exchange_propagates_best_design() {
        let m = zoo::c3d_tiny();
        let dev = device::by_name("zcu102").unwrap();
        let rm = ResourceModel::fit(1, 120);
        let opt = Optimizer::new(&m, &dev, &rm, OptCfg::fast(3));
        let mut chains = vec![
            Chain::new(&opt, 0).unwrap(),
            Chain::new(&opt, 1).unwrap(),
        ];
        // Anneal chain 0 to completion so it holds an improved best;
        // chain 1 stays at the (shared) warm start.
        while !chains[0].done() {
            chains[0].step_temp();
        }
        let donor_best = chains[0].best_latency();
        assert!(donor_best <= chains[1].current_latency());
        exchange(&mut chains);
        // Post-exchange, chain 1's best can be no worse than the
        // donor's (it either adopted the design or already matched it).
        assert!(chains[1].best_latency() <= donor_best);
    }

    #[test]
    fn merged_history_is_monotone() {
        let a = vec![(0usize, 10.0), (4, 8.0), (9, 5.0)];
        let b = vec![(0usize, 10.0), (2, 9.0), (9, 4.0), (12, 3.0)];
        let mk = |history: Vec<(usize, f64)>| OptResult {
            design: crate::sdf::Design::initial(&zoo::c3d_tiny()),
            latency_cycles: history.last().unwrap().1,
            latency_ms: history.last().unwrap().1,
            resources: crate::device::Resources::ZERO,
            history,
            accepted: vec![],
            iterations: 20,
            accepted_moves: 5,
        };
        let merged = merge(vec![mk(a), mk(b)]);
        assert_eq!(merged.iterations, 40);
        assert!(merged
            .history
            .windows(2)
            .all(|w| w[1].1 < w[0].1 && w[1].0 >= w[0].0));
        assert_eq!(merged.history.first(), Some(&(0usize, 10.0)));
        assert_eq!(merged.history.last(), Some(&(12usize, 3.0)));
    }
}
