//! Latency-driven design space exploration (§V): simulated annealing
//! (Algorithm 2) over the transformation set of §V-C.
//!
//! Moves: feature-map dimension reshaping, coarse-grain folding,
//! fine-grain folding, combination/separation of computation nodes.
//! Activation fusion (§VII-A1) is applied at initialisation when
//! enabled. Every candidate is validated against the §V-B constraints
//! (resources within the device, folding divisibility, schedulable
//! parameters) before evaluation; latency evaluation is *incremental*:
//! a move touches one or two nodes, so only the layers mapped to those
//! nodes are re-scheduled.

pub mod transforms;

use crate::device::{Device, Resources};
use crate::model::layer::LayerKind;
use crate::model::ModelGraph;
use crate::perf::BwEnv;
use crate::resource::ResourceModel;
use crate::sched::{self, SchedCfg};
use crate::sdf::{Design, MapTarget};
use crate::util::rng::Rng;

/// Optimiser configuration — the paper's SA hyper-parameters
/// (§VII-A1 baseline: tau_start 10, tau_min 1e-6, cooling 0.99) plus
/// the ablation feature toggles.
#[derive(Debug, Clone)]
pub struct OptCfg {
    pub seed: u64,
    pub tau_start: f64,
    pub tau_min: f64,
    pub cooling: f64,
    /// Moves evaluated per temperature step.
    pub iters_per_temp: usize,
    /// `Combination and Separation of Computation Nodes` transform.
    pub enable_combine: bool,
    /// Fusion of activation/scale layers into the preceding layer.
    pub enable_fusion: bool,
    /// Runtime-parameterized computation nodes.
    pub runtime_params: bool,
    /// `L_e` — execution nodes detached per separation move.
    pub l_e: usize,
    /// `N_c` — computation nodes merged per combination move.
    pub n_c: usize,
}

impl Default for OptCfg {
    fn default() -> Self {
        OptCfg {
            seed: 0xCAFE,
            tau_start: 10.0,
            tau_min: 1e-6,
            cooling: 0.99,
            iters_per_temp: 8,
            enable_combine: true,
            enable_fusion: true,
            runtime_params: true,
            l_e: 2,
            n_c: 2,
        }
    }
}

impl OptCfg {
    /// Quick preset for tests/benches: fewer temperature steps.
    pub fn fast(seed: u64) -> OptCfg {
        OptCfg { seed, tau_min: 1e-2, iters_per_temp: 2,
                 ..OptCfg::default() }
    }
}

/// Optimisation outcome + traces for Figs 4 and 7.
#[derive(Debug, Clone)]
pub struct OptResult {
    pub design: Design,
    pub latency_cycles: f64,
    pub latency_ms: f64,
    pub resources: Resources,
    /// (iteration, best-so-far latency ms) — Fig 4.
    pub history: Vec<(usize, f64)>,
    /// (DSP count, latency ms) of every accepted feasible state —
    /// the Fig 7 pareto cloud.
    pub accepted: Vec<(f64, f64)>,
    pub iterations: usize,
    pub accepted_moves: usize,
}

/// Incremental latency state: per-layer latencies + total.
struct LatencyState {
    per_layer: Vec<f64>,
    total: f64,
}

impl LatencyState {
    fn full(model: &ModelGraph, design: &Design, env: &BwEnv,
            cfg: &SchedCfg) -> LatencyState {
        let per_layer: Vec<f64> = (0..model.layers.len())
            .map(|l| sched::layer_latency(model, design, l, env, cfg))
            .collect();
        let total = per_layer.iter().sum();
        LatencyState { per_layer, total }
    }

    /// Recompute only the layers mapped to `nodes`.
    fn update(&mut self, model: &ModelGraph, design: &Design, env: &BwEnv,
              cfg: &SchedCfg, nodes: &[usize]) {
        for (l, m) in design.mapping.iter().enumerate() {
            let dirty = match m {
                MapTarget::Node(i) => nodes.contains(i),
                MapTarget::Fused => false,
            };
            if dirty {
                let new = sched::layer_latency(model, design, l, env, cfg);
                self.total += new - self.per_layer[l];
                self.per_layer[l] = new;
            }
        }
    }
}

pub struct Optimizer<'a> {
    pub model: &'a ModelGraph,
    pub device: &'a Device,
    pub rm: &'a ResourceModel,
    pub cfg: OptCfg,
}

impl<'a> Optimizer<'a> {
    pub fn new(model: &'a ModelGraph, device: &'a Device,
               rm: &'a ResourceModel, cfg: OptCfg) -> Self {
        Optimizer { model, device, rm, cfg }
    }

    fn sched_cfg(&self) -> SchedCfg {
        SchedCfg { runtime_params: self.cfg.runtime_params }
    }

    /// Warm start (§VII-A1): the initial design, shrunk until it fits
    /// the device, with fusion applied when enabled.
    ///
    /// Runtime-parameterized nodes start all-combined (per type and
    /// kernel class — tiles make sharing cheap). Non-runtime hardware
    /// pads every execution to the node's compile-time maximum, so
    /// sharing differently-shaped layers is catastrophic there: the
    /// baseline starts from the paper's pre-combination mapping (one
    /// node per layer) and the combination transform merges only
    /// where profitable.
    pub fn warm_start(&self) -> Result<Design, String> {
        let mut design = if self.cfg.runtime_params {
            Design::initial(self.model)
        } else {
            Design::initial_per_layer(self.model)
        };
        if self.cfg.enable_fusion {
            transforms::fuse_all(self.model, &mut design);
            design.compact();
        }
        // Memory-bound node types (act/eltwise/gap/pool) consume no
        // DSPs; give them enough stream parallelism up front to meet
        // the DMA bandwidth — SA still tunes them, but the warm start
        // should not leave the memory-bound side at 1 word/cycle.
        // (Shared-node mode only: the per-layer baseline has ~100
        // such nodes and the stream LUT cost would sink it.)
        if self.cfg.runtime_params {
            let bw = BwEnv::of_device(self.device).bw_in.ceil() as usize;
            for node in &mut design.nodes {
                use crate::sdf::NodeKind;
                if matches!(node.kind, NodeKind::Act | NodeKind::Eltwise
                            | NodeKind::Gap | NodeKind::Pool) {
                    node.coarse_in = crate::util::math::max_factor_leq(
                        node.max_in.c, bw.max(1));
                    node.coarse_out = node.coarse_in;
                }
            }
        }
        // Shrink over-sized nodes until the resource constraint holds.
        let mut guard = 0;
        while !self
            .rm
            .design_resources(&design)
            .fits(&self.device.avail)
        {
            guard += 1;
            if guard > 4096 {
                return Err(format!(
                    "warm start cannot fit {} on {}",
                    self.model.name, self.device.name
                ));
            }
            transforms::shrink_largest(self.model, &mut design, self.rm);
        }
        design.validate(self.model)?;
        Ok(design)
    }

    /// Run Algorithm 2.
    pub fn run(&self) -> Result<OptResult, String> {
        let env = BwEnv::of_device(self.device);
        let scfg = self.sched_cfg();
        let mut rng = Rng::new(self.cfg.seed);
        let mut design = self.warm_start()?;
        let mut lat = LatencyState::full(self.model, &design, &env, &scfg);
        let mut best = design.clone();
        let mut best_lat = lat.total;
        let mut history = Vec::new();
        let mut accepted = Vec::new();
        let mut tau = self.cfg.tau_start;
        let mut iter = 0usize;
        let mut accepted_moves = 0usize;
        let cycles_per_ms = self.device.cycles_per_ms();
        history.push((0, best_lat / cycles_per_ms));

        while tau > self.cfg.tau_min {
            for _ in 0..self.cfg.iters_per_temp {
                iter += 1;
                let prev_total = lat.total;
                let mut cand = design.clone();
                let touched = transforms::random_move(
                    self.model, &mut cand, &mut rng, &self.cfg);
                let Some(touched) = touched else { continue };
                // Constraint check (§V-B): structure + resources. Only
                // the touched nodes can have changed (the full
                // `validate` runs in debug builds and on the result).
                if cand.validate_nodes(self.model, &touched).is_err() {
                    continue;
                }
                debug_assert_eq!(cand.validate(self.model), Ok(()));
                let cand_res = self.rm.design_resources(&cand);
                if !cand_res.fits(&self.device.avail) {
                    continue;
                }
                let mut cand_lat = LatencyState {
                    per_layer: lat.per_layer.clone(),
                    total: lat.total,
                };
                cand_lat.update(self.model, &cand, &env, &scfg, &touched);
                // Fused layers may have been (un)changed by the move.
                let new_total = cand_lat.total;

                let accept = if new_total < prev_total {
                    true
                } else {
                    // Relative-delta Metropolis rule (Algorithm 2's
                    // psi, normalised so tau is unitless).
                    let delta = (new_total - prev_total)
                        / prev_total.max(1.0);
                    rng.uniform() < (-delta / tau.max(1e-12)).exp()
                };
                if accept {
                    design = cand;
                    lat = cand_lat;
                    accepted_moves += 1;
                    accepted.push((cand_res.dsp,
                                   lat.total / cycles_per_ms));
                    if lat.total < best_lat {
                        best_lat = lat.total;
                        best = design.clone();
                        history.push((iter, best_lat / cycles_per_ms));
                    }
                }
            }
            tau *= self.cfg.cooling;
        }
        best.compact();
        let resources = self.rm.design_resources(&best);
        Ok(OptResult {
            latency_cycles: best_lat,
            latency_ms: best_lat / cycles_per_ms,
            design: best,
            resources,
            history,
            accepted,
            iterations: iter,
            accepted_moves,
        })
    }
}

/// Convenience wrapper: optimise `model` for `device`.
pub fn optimize(model: &ModelGraph, device: &Device, rm: &ResourceModel,
                cfg: OptCfg) -> Result<OptResult, String> {
    Optimizer::new(model, device, rm, cfg).run()
}

/// Best-of-N restarts (SA is stochastic; the toolflow launches a small
/// portfolio of annealing runs in parallel threads and keeps the best
/// design — restarts are embarrassingly parallel).
pub fn optimize_multi(model: &ModelGraph, device: &Device,
                      rm: &ResourceModel, cfg: OptCfg, n_seeds: u64)
    -> Result<OptResult, String> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_seeds)
            .map(|i| {
                let cfg_i = OptCfg {
                    seed: cfg.seed.wrapping_add(i.wrapping_mul(0x9E37)),
                    ..cfg.clone()
                };
                scope.spawn(move || optimize(model, device, rm, cfg_i))
            })
            .collect();
        let mut best: Option<OptResult> = None;
        for h in handles {
            let r = h.join().map_err(|_| "SA worker panicked")??;
            best = Some(match best {
                Some(b) if b.latency_cycles <= r.latency_cycles => b,
                _ => r,
            });
        }
        best.ok_or_else(|| "no seeds".to_string())
    })
}

/// Layers eligible for fusion: Activation/Scale whose producer chain
/// bottoms out in a compute layer (conv/fc/eltwise).
pub fn fusable_layers(model: &ModelGraph) -> Vec<usize> {
    (0..model.layers.len())
        .filter(|&l| {
            matches!(model.layers[l].kind,
                     LayerKind::Activation(_) | LayerKind::Scale)
                && model.layers[l].inputs.first().is_some()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device;
    use crate::model::zoo;

    fn rm() -> ResourceModel {
        ResourceModel::fit(1, 120)
    }

    #[test]
    fn optimizes_tiny_model() {
        let m = zoo::c3d_tiny();
        let dev = device::by_name("zcu102").unwrap();
        let rm = rm();
        let r = optimize(&m, &dev, &rm, OptCfg::fast(7)).unwrap();
        assert!(r.latency_ms > 0.0);
        assert!(r.resources.fits(&dev.avail));
        assert_eq!(r.design.validate(&m), Ok(()));
        assert!(r.iterations > 100);
    }

    #[test]
    fn improves_over_warm_start() {
        let m = zoo::c3d_tiny();
        let dev = device::by_name("zcu102").unwrap();
        let rm = rm();
        let opt = Optimizer::new(&m, &dev, &rm, OptCfg::fast(7));
        let ws = opt.warm_start().unwrap();
        let env = BwEnv::of_device(&dev);
        let ws_lat = sched::total_latency_cycles(
            &m, &ws, &env, &SchedCfg::default());
        let r = opt.run().unwrap();
        assert!(r.latency_cycles <= ws_lat,
                "SA {} > warm start {}", r.latency_cycles, ws_lat);
    }

    #[test]
    fn deterministic_for_seed() {
        let m = zoo::c3d_tiny();
        let dev = device::by_name("zcu102").unwrap();
        let rm = rm();
        let a = optimize(&m, &dev, &rm, OptCfg::fast(3)).unwrap();
        let b = optimize(&m, &dev, &rm, OptCfg::fast(3)).unwrap();
        assert_eq!(a.latency_cycles, b.latency_cycles);
        assert_eq!(a.accepted_moves, b.accepted_moves);
    }

    #[test]
    fn history_is_monotone_decreasing() {
        let m = zoo::c3d_tiny();
        let dev = device::by_name("zcu102").unwrap();
        let rm = rm();
        let r = optimize(&m, &dev, &rm, OptCfg::fast(5)).unwrap();
        assert!(r
            .history
            .windows(2)
            .all(|w| w[1].1 <= w[0].1 && w[1].0 >= w[0].0));
    }

    #[test]
    fn fusion_reduces_latency() {
        let m = zoo::c3d_tiny();
        let dev = device::by_name("zcu102").unwrap();
        let rm = rm();
        let base = optimize(&m, &dev, &rm, OptCfg {
            enable_fusion: false,
            ..OptCfg::fast(9)
        })
        .unwrap();
        let fused = optimize(&m, &dev, &rm, OptCfg::fast(9)).unwrap();
        assert!(fused.latency_ms < base.latency_ms,
                "fused {} >= base {}", fused.latency_ms, base.latency_ms);
    }

    #[test]
    fn runtime_params_speedup_large() {
        // The §VII-A1 headline: runtime reconfiguration gives a large
        // boost on models whose layers span many feature-map scales —
        // shared nodes must otherwise pad everything to the maximum.
        // The paper's ablation model (R(2+1)D-18) shows 18.21x; the
        // full reproduction is in report/ablation — here we assert the
        // effect's direction and rough magnitude (>2x) on a quick run.
        let m = zoo::r2plus1d_18();
        let dev = device::by_name("zcu102").unwrap();
        let rm = rm();
        let padded = optimize(&m, &dev, &rm, OptCfg {
            runtime_params: false,
            ..OptCfg::fast(11)
        })
        .unwrap();
        let rt = optimize(&m, &dev, &rm, OptCfg::fast(11)).unwrap();
        assert!(rt.latency_ms * 2.0 < padded.latency_ms,
                "rt {} vs padded {}", rt.latency_ms, padded.latency_ms);
    }
}
