//! Latency-driven design space exploration (§V): simulated annealing
//! (Algorithm 2) over the transformation set of §V-C.
//!
//! Moves: feature-map dimension reshaping, coarse-grain folding,
//! fine-grain folding, combination/separation of computation nodes.
//! Activation fusion (§VII-A1) is applied at initialisation when
//! enabled. Every candidate is validated against the §V-B constraints
//! (resources within the device, folding divisibility, schedulable
//! parameters) before evaluation.
//!
//! The engine is *zero-clone and fully incremental*: moves mutate one
//! working design in place and are rolled back from an [`UndoLog`] on
//! rejection; per-node resources are cached and delta-repriced
//! ([`NodeResCache`]); the dirty layer set comes from a node→layers
//! reverse index ([`MappingIndex`]); and per-layer latencies are
//! memoised on the (layer, node parameters) pair ([`LatencyMemo`]).
//! Every cached quantity is bit-exact against from-scratch
//! recomputation, so results are identical to the naive engine — just
//! without the O(design) clone + full resource sweep per candidate
//! that used to dominate DSE states/second.

pub mod parallel;
pub mod transforms;

use crate::device::{Device, Resources};
use crate::model::layer::LayerKind;
use crate::model::ModelGraph;
use crate::obs::{SaOutcome, SaSample, SaTelemetry};
use crate::perf::BwEnv;
use crate::resource::{NodeResCache, ResourceModel};
use crate::sched::{self, LatencyMemo, SchedCfg};
use crate::sdf::{Design, MapTarget, UndoLog};
use crate::util::rng::Rng;

/// Optimiser configuration — the paper's SA hyper-parameters
/// (§VII-A1 baseline: tau_start 10, tau_min 1e-6, cooling 0.99) plus
/// the ablation feature toggles.
#[derive(Debug, Clone)]
pub struct OptCfg {
    pub seed: u64,
    pub tau_start: f64,
    pub tau_min: f64,
    pub cooling: f64,
    /// Moves evaluated per temperature step.
    pub iters_per_temp: usize,
    /// `Combination and Separation of Computation Nodes` transform.
    pub enable_combine: bool,
    /// Fusion of activation/scale layers into the preceding layer.
    pub enable_fusion: bool,
    /// Runtime-parameterized computation nodes.
    pub runtime_params: bool,
    /// `L_e` — execution nodes detached per separation move.
    pub l_e: usize,
    /// `N_c` — computation nodes merged per combination move.
    pub n_c: usize,
    /// Wordlength configuration (quant subsystem). `None` — the
    /// default — is the paper's fixed 16-bit datapath and keeps the
    /// engine bit-identical to the historical one (same RNG stream,
    /// same accepted-move traces). `Some` stamps the configured
    /// per-layer widths onto the warm start and, when
    /// [`crate::quant::QuantCfg::search`] is set, adds the SA
    /// wordlength move under the SQNR budget.
    pub quant: Option<crate::quant::QuantCfg>,
}

impl Default for OptCfg {
    fn default() -> Self {
        OptCfg {
            seed: 0xCAFE,
            tau_start: 10.0,
            tau_min: 1e-6,
            cooling: 0.99,
            iters_per_temp: 8,
            enable_combine: true,
            enable_fusion: true,
            runtime_params: true,
            l_e: 2,
            n_c: 2,
            quant: None,
        }
    }
}

impl OptCfg {
    /// Quick preset for tests/benches: fewer temperature steps.
    pub fn fast(seed: u64) -> OptCfg {
        OptCfg { seed, tau_min: 1e-2, iters_per_temp: 2,
                 ..OptCfg::default() }
    }

    /// Is the SA wordlength move enabled (quant config present with
    /// `search`)?
    pub fn quant_search(&self) -> bool {
        self.quant.as_ref().is_some_and(|q| q.search)
    }
}

/// Optimisation outcome + traces for Figs 4 and 7.
#[derive(Debug, Clone)]
pub struct OptResult {
    pub design: Design,
    pub latency_cycles: f64,
    pub latency_ms: f64,
    pub resources: Resources,
    /// (iteration, best-so-far latency ms) — Fig 4.
    pub history: Vec<(usize, f64)>,
    /// (DSP count, latency ms) of every accepted feasible state —
    /// the Fig 7 pareto cloud.
    pub accepted: Vec<(f64, f64)>,
    pub iterations: usize,
    pub accepted_moves: usize,
}

/// Incremental latency state: per-layer latencies + total.
#[derive(Debug, Clone)]
pub struct LatencyState {
    pub per_layer: Vec<f64>,
    pub total: f64,
}

impl LatencyState {
    pub fn full(model: &ModelGraph, design: &Design, env: &BwEnv,
                cfg: &SchedCfg) -> LatencyState {
        let per_layer: Vec<f64> = (0..model.layers.len())
            .map(|l| sched::layer_latency(model, design, l, env, cfg))
            .collect();
        let total = per_layer.iter().sum();
        LatencyState { per_layer, total }
    }
}

/// Node → mapped-layers reverse index (the inverse mapping `E(n)` for
/// every node at once). The old `LatencyState::update` found a move's
/// dirty layers by scanning the whole mapping with `nodes.contains(i)`
/// — O(L·T) per candidate, ruinous at X3D-M scale (396 layers); the
/// index makes it O(|dirty|). Updated incrementally from each move's
/// [`UndoLog`] mapping edits, with an exact inverse for rejection.
#[derive(Debug, Clone)]
pub struct MappingIndex {
    layers: Vec<Vec<usize>>,
}

impl MappingIndex {
    pub fn new(design: &Design) -> MappingIndex {
        let mut layers = vec![Vec::new(); design.nodes.len()];
        for (l, m) in design.mapping.iter().enumerate() {
            if let MapTarget::Node(i) = m {
                layers[*i].push(l);
            }
        }
        MappingIndex { layers }
    }

    /// Layers currently mapped to node `n` (unsorted).
    pub fn layers_of(&self, n: usize) -> &[usize] {
        &self.layers[n]
    }

    pub fn is_used(&self, n: usize) -> bool {
        n < self.layers.len() && !self.layers[n].is_empty()
    }

    /// Fold a move's mapping edits in: each edited layer is moved from
    /// its pre-move node list to its current (post-move) one. `design`
    /// must be in the post-move state.
    pub fn apply(&mut self, design: &Design,
                 edits: &[(usize, MapTarget)]) {
        if design.nodes.len() > self.layers.len() {
            self.layers.resize(design.nodes.len(), Vec::new());
        }
        for &(l, old) in edits {
            let new = design.mapping[l];
            if old == new {
                continue;
            }
            if let MapTarget::Node(i) = old {
                let v = &mut self.layers[i];
                if let Some(p) = v.iter().position(|&x| x == l) {
                    v.swap_remove(p);
                }
            }
            if let MapTarget::Node(i) = new {
                self.layers[i].push(l);
            }
        }
    }

    /// Exact inverse of [`MappingIndex::apply`]. Must run while
    /// `design` is still in the post-move state (before
    /// `UndoLog::undo`), because the current mapping tells us where
    /// each edited layer has to be removed from.
    pub fn rollback(&mut self, design: &Design,
                    edits: &[(usize, MapTarget)], old_nodes_len: usize) {
        for &(l, old) in edits {
            let new = design.mapping[l];
            if old == new {
                continue;
            }
            if let MapTarget::Node(i) = new {
                let v = &mut self.layers[i];
                if let Some(p) = v.iter().position(|&x| x == l) {
                    v.swap_remove(p);
                }
            }
            if let MapTarget::Node(i) = old {
                self.layers[i].push(l);
            }
        }
        self.layers.truncate(old_nodes_len);
    }
}

/// The zero-clone candidate evaluator behind `Optimizer::run`.
///
/// One working `Design` is mutated in place by
/// `transforms::random_move_logged`; this struct prices the mutated
/// state *incrementally* — per-node resources through a
/// [`NodeResCache`] delta reprice, per-layer latencies through the
/// [`MappingIndex`] dirty set and the [`LatencyMemo`] — and can
/// restore every piece of derived state exactly when the move is
/// rejected. All cached quantities are bit-identical to from-scratch
/// recomputation (the equivalence property test in
/// `rust/tests/incremental.rs` drives exactly that invariant), so the
/// accepted-move sequence matches the historical clone-per-candidate
/// engine for any seed.
pub struct IncrementalEval {
    pub lat: LatencyState,
    pub index: MappingIndex,
    pub cache: NodeResCache,
    pub memo: LatencyMemo,
    /// Scratch: dirty layer set of the current move (sorted ascending
    /// so the f64 accumulation order matches a full-mapping scan).
    dirty: Vec<usize>,
    /// Scratch: (layer, pre-move latency) pairs for rejection.
    lat_saved: Vec<(usize, f64)>,
    lat_total_saved: f64,
    lat_dirty: bool,
}

impl IncrementalEval {
    pub fn new(model: &ModelGraph, design: &Design, rm: &ResourceModel,
               env: &BwEnv, scfg: &SchedCfg) -> IncrementalEval {
        Self::with_memo(model, design, rm, env, scfg,
                        LatencyMemo::new())
    }

    /// Like [`IncrementalEval::new`] but seeded with an existing
    /// latency memo. Memo entries are keyed on `(layer, node
    /// parameters)` and are valid for any design of the same model and
    /// environment, so a chain that swaps designs (best-so-far
    /// exchange) keeps its warm cache instead of re-deriving every
    /// per-layer latency.
    pub fn with_memo(model: &ModelGraph, design: &Design,
                     rm: &ResourceModel, env: &BwEnv, scfg: &SchedCfg,
                     mut memo: LatencyMemo) -> IncrementalEval {
        let per_layer: Vec<f64> = (0..model.layers.len())
            .map(|l| memo.layer_latency(model, design, l, env, scfg))
            .collect();
        let total = per_layer.iter().sum();
        IncrementalEval {
            lat: LatencyState { per_layer, total },
            index: MappingIndex::new(design),
            cache: NodeResCache::new(rm, design),
            memo,
            dirty: Vec::new(),
            lat_saved: Vec::new(),
            lat_total_saved: 0.0,
            lat_dirty: false,
        }
    }

    /// Total `R_total` of the current state from the cache.
    pub fn resources(&self) -> Resources {
        let index = &self.index;
        self.cache.total(|i| index.is_used(i))
    }

    /// Step 1 after a logged move: fold the mapping edits into the
    /// reverse index, delta-reprice the touched nodes, and return the
    /// candidate's `R_total` (for the §V-B resource constraint).
    pub fn price_move(&mut self, design: &Design, rm: &ResourceModel,
                      log: &UndoLog, touched: &[usize]) -> Resources {
        self.index.apply(design, log.mapping_edits());
        self.cache.reprice(rm, design, touched);
        self.lat_dirty = false;
        self.resources()
    }

    /// Step 2 (feasible candidates only): re-evaluate the layers
    /// mapped to the touched nodes and return the candidate's total
    /// latency. The previous per-layer values are kept for `reject`.
    pub fn eval_latency(&mut self, model: &ModelGraph, design: &Design,
                        env: &BwEnv, scfg: &SchedCfg,
                        touched: &[usize]) -> f64 {
        self.dirty.clear();
        for &n in touched {
            self.dirty.extend_from_slice(self.index.layers_of(n));
        }
        self.dirty.sort_unstable();
        // A duplicate node index in `touched` would list its layers
        // twice; the second pass would snapshot already-updated values
        // and break `reject` (same contract as NodeResCache::reprice).
        self.dirty.dedup();
        self.lat_total_saved = self.lat.total;
        self.lat_saved.clear();
        for i in 0..self.dirty.len() {
            let l = self.dirty[i];
            let new = self.memo.layer_latency(model, design, l, env, scfg);
            self.lat_saved.push((l, self.lat.per_layer[l]));
            self.lat.total += new - self.lat.per_layer[l];
            self.lat.per_layer[l] = new;
        }
        self.lat_dirty = true;
        self.lat.total
    }

    /// Accept the current candidate: speculative cache entries become
    /// permanent; the design stays as mutated.
    pub fn commit(&mut self) {
        self.cache.commit();
        self.lat_dirty = false;
    }

    /// Reject the current candidate: restores latency state, resource
    /// cache, and reverse index, then rolls the design itself back via
    /// the undo log. Only valid after `price_move` (with or without a
    /// subsequent `eval_latency`).
    pub fn reject(&mut self, design: &mut Design, log: &mut UndoLog) {
        if self.lat_dirty {
            for &(l, old) in &self.lat_saved {
                self.lat.per_layer[l] = old;
            }
            self.lat.total = self.lat_total_saved;
            self.lat_dirty = false;
        }
        self.cache.rollback();
        self.index.rollback(design, log.mapping_edits(),
                            log.old_nodes_len());
        log.undo(design);
    }
}

pub struct Optimizer<'a> {
    pub model: &'a ModelGraph,
    pub device: &'a Device,
    pub rm: &'a ResourceModel,
    pub cfg: OptCfg,
}

impl<'a> Optimizer<'a> {
    pub fn new(model: &'a ModelGraph, device: &'a Device,
               rm: &'a ResourceModel, cfg: OptCfg) -> Self {
        Optimizer { model, device, rm, cfg }
    }

    fn sched_cfg(&self) -> SchedCfg {
        SchedCfg { runtime_params: self.cfg.runtime_params }
    }

    /// Warm start (§VII-A1): the initial design, shrunk until it fits
    /// the device, with fusion applied when enabled.
    ///
    /// Runtime-parameterized nodes start all-combined (per type and
    /// kernel class — tiles make sharing cheap). Non-runtime hardware
    /// pads every execution to the node's compile-time maximum, so
    /// sharing differently-shaped layers is catastrophic there: the
    /// baseline starts from the paper's pre-combination mapping (one
    /// node per layer) and the combination transform merges only
    /// where profitable.
    pub fn warm_start(&self) -> Result<Design, String> {
        let mut design = if self.cfg.runtime_params {
            Design::initial(self.model)
        } else {
            Design::initial_per_layer(self.model)
        };
        if self.cfg.enable_fusion {
            transforms::fuse_all(self.model, &mut design);
            design.compact();
        }
        // Quant subsystem: stamp the configured per-layer wordlengths
        // onto the nodes (max over mapped layers) and reject a
        // configuration that already busts the accuracy budget. The
        // budget is a *hard* constraint over the whole annealing
        // trajectory — the search explores only feasible
        // configurations and cannot traverse an infeasible start — so
        // the configured widths must satisfy it up front in both
        // modes. Uniform 16-bit stamps are no-ops, keeping the
        // historical warm start bit-identical.
        if let Some(q) = &self.cfg.quant {
            let widths = q.resolve(self.model)?;
            crate::quant::apply_to_design(self.model, &mut design,
                                          &widths);
            let sqnr = crate::quant::design_sqnr_db(
                self.model, &design, &mut Vec::new());
            if sqnr < q.min_sqnr_db {
                return Err(format!(
                    "quant: configured wordlengths give SQNR \
                     {sqnr:.1} dB, below the {:.1} dB budget — raise \
                     the starting widths or lower the budget \
                     (--min-sqnr-db)",
                    q.min_sqnr_db));
            }
        }
        // Memory-bound node types (act/eltwise/gap/pool) consume no
        // DSPs; give them enough stream parallelism up front to meet
        // the DMA bandwidth — SA still tunes them, but the warm start
        // should not leave the memory-bound side at 1 word/cycle.
        // (Shared-node mode only: the per-layer baseline has ~100
        // such nodes and the stream LUT cost would sink it.)
        if self.cfg.runtime_params {
            let bw = BwEnv::of_device(self.device).bw_in.ceil() as usize;
            for node in &mut design.nodes {
                use crate::sdf::NodeKind;
                if matches!(node.kind, NodeKind::Act | NodeKind::Eltwise
                            | NodeKind::Gap | NodeKind::Pool) {
                    node.coarse_in = crate::util::math::max_factor_leq(
                        node.max_in.c, bw.max(1));
                    node.coarse_out = node.coarse_in;
                }
            }
        }
        // Shrink over-sized nodes until the resource constraint holds.
        let mut guard = 0;
        while !self
            .rm
            .design_resources(&design)
            .fits(&self.device.avail)
        {
            guard += 1;
            if guard > 4096 {
                return Err(format!(
                    "warm start cannot fit {} on {}",
                    self.model.name, self.device.name
                ));
            }
            transforms::shrink_largest(self.model, &mut design, self.rm);
        }
        design.validate(self.model)?;
        Ok(design)
    }

    /// Run Algorithm 2 — zero-clone: one working design is mutated in
    /// place per proposed move and rolled back from the [`UndoLog`] on
    /// rejection; `Design::clone` only happens when a new best is
    /// found. Candidate costs come from the [`IncrementalEval`]
    /// caches, which are exact, so the accepted-move sequence for a
    /// given seed is identical to the clone-per-candidate engine this
    /// replaces.
    ///
    /// Implemented as a single [`Chain`] driven to completion — the
    /// multi-chain engine (`optim::parallel`) runs K of these
    /// concurrently with periodic best exchange, and chain stream 0 is
    /// bit-identical to this sequential path by construction.
    pub fn run(&self) -> Result<OptResult, String> {
        let mut chain = Chain::new(self, 0)?;
        while !chain.done() {
            chain.step_temp();
        }
        let r = chain.finish();
        // Full §V-B validation of the result in every build profile —
        // this replaced the per-move `debug_assert_eq!` that compiled
        // out of release builds.
        r.design.validate(self.model).map_err(|e| {
            format!("optimizer produced an invalid design: {e}")
        })?;
        Ok(r)
    }
}

/// One annealing chain: the complete per-chain state of Algorithm 2 —
/// working design, [`IncrementalEval`] caches (`NodeResCache`,
/// `LatencyMemo`, `MappingIndex`), undo log, RNG stream, temperature,
/// and best-so-far traces. `Optimizer::run` drives exactly one chain;
/// `optim::parallel` owns K of them, one per thread, and exchanges
/// best designs between temperature rounds. Every piece of cached
/// state lives inside the chain, so chains share nothing mutable and
/// are `Send` across worker threads.
pub struct Chain<'a> {
    model: &'a ModelGraph,
    device: &'a Device,
    rm: &'a ResourceModel,
    cfg: OptCfg,
    env: BwEnv,
    scfg: SchedCfg,
    design: Design,
    ev: IncrementalEval,
    log: UndoLog,
    rng: Rng,
    best: Design,
    best_lat: f64,
    history: Vec<(usize, f64)>,
    accepted: Vec<(f64, f64)>,
    tau: f64,
    iter: usize,
    accepted_moves: usize,
    cycles_per_ms: f64,
    /// SQNR floor (dB) every candidate must keep — set only when the
    /// wordlength search is on (widths never shrink otherwise, so the
    /// warm-start budget check suffices and the per-move O(L) proxy
    /// evaluation is skipped).
    quant_floor: Option<f64>,
    /// Scratch noise buffer + precomputed model sink mask for the
    /// SQNR proxy (no per-candidate allocation on the hot path).
    sqnr_scratch: Vec<f64>,
    sqnr_sinks: Vec<bool>,
    /// SA convergence telemetry (obs subsystem), recorded only when
    /// enabled via [`Chain::enable_telemetry`]. Recording draws no RNG
    /// and changes no float computation, so traced and untraced chains
    /// stay bit-identical; the disabled path is one `is-None` branch
    /// per sample point (hot-path contract of `ci/check_bench.py`).
    telemetry: Option<Box<SaTelemetry>>,
}

impl<'a> Chain<'a> {
    /// Warm-start a chain on RNG stream `stream` of the optimiser's
    /// seed (stream 0 == the base seed, pinning sequential
    /// equivalence). All chains of one run start from the same
    /// (deterministic) warm design and diverge only through their RNG
    /// streams.
    pub fn new(opt: &Optimizer<'a>, stream: u64)
        -> Result<Chain<'a>, String> {
        let env = BwEnv::of_device(opt.device);
        let scfg = opt.sched_cfg();
        let design = opt.warm_start()?;
        let ev = IncrementalEval::new(opt.model, &design, opt.rm, &env,
                                      &scfg);
        let best = design.clone();
        let best_lat = ev.lat.total;
        let cycles_per_ms = opt.device.cycles_per_ms();
        let quant_floor = opt
            .cfg
            .quant
            .as_ref()
            .filter(|q| q.search)
            .map(|q| q.min_sqnr_db);
        let sqnr_sinks = if quant_floor.is_some() {
            crate::quant::sink_mask(opt.model)
        } else {
            Vec::new()
        };
        Ok(Chain {
            model: opt.model,
            device: opt.device,
            rm: opt.rm,
            cfg: opt.cfg.clone(),
            env,
            scfg,
            design,
            ev,
            log: UndoLog::new(),
            rng: Rng::stream(opt.cfg.seed, stream),
            best,
            best_lat,
            history: vec![(0, best_lat / cycles_per_ms)],
            accepted: Vec::new(),
            tau: opt.cfg.tau_start,
            iter: 0,
            accepted_moves: 0,
            cycles_per_ms,
            quant_floor,
            sqnr_scratch: Vec::new(),
            sqnr_sinks,
            telemetry: None,
        })
    }

    /// Start recording SA convergence telemetry under chain index
    /// `chain` (the RNG stream / restart index, used as the Perfetto
    /// track id).
    pub fn enable_telemetry(&mut self, chain: u64) {
        self.telemetry = Some(Box::new(SaTelemetry::new(chain)));
    }

    /// Take the recorded telemetry (None when never enabled). Call
    /// before [`Chain::finish`] consumes the chain.
    pub fn take_telemetry(&mut self) -> Option<SaTelemetry> {
        self.telemetry.take().map(|t| *t)
    }

    /// Record one telemetry sample for a move that produced a
    /// candidate. `cand_cycles` is the candidate's latency where it
    /// was priced, or the incumbent's for infeasible candidates.
    fn record_sample(&mut self, kind: transforms::MoveKind,
                     outcome: SaOutcome, cand_cycles: f64) {
        if let Some(t) = self.telemetry.as_deref_mut() {
            t.samples.push(SaSample {
                iter: self.iter,
                kind: kind.name(),
                outcome,
                cand_ms: cand_cycles / self.cycles_per_ms,
                best_ms: self.best_lat / self.cycles_per_ms,
                tau: self.tau,
            });
        }
    }

    /// Annealing complete (temperature at/below the floor)?
    pub fn done(&self) -> bool {
        self.tau <= self.cfg.tau_min
    }

    /// Latency of the current working design (cycles).
    pub fn current_latency(&self) -> f64 {
        self.ev.lat.total
    }

    /// Best latency found by this chain so far (cycles).
    pub fn best_latency(&self) -> f64 {
        self.best_lat
    }

    /// One temperature step: `iters_per_temp` proposed moves, then
    /// cool. No-op once `done()`.
    pub fn step_temp(&mut self) {
        if self.done() {
            return;
        }
        for _ in 0..self.cfg.iters_per_temp {
            self.iter += 1;
            let prev_total = self.ev.lat.total;
            self.log.begin(&self.design);
            let touched = transforms::random_move_logged_kind(
                self.model, &mut self.design, &mut self.rng, &self.cfg,
                &mut self.log);
            let Some((kind, touched)) = touched else {
                self.log.undo(&mut self.design); // no-op: nothing logged
                continue;
            };
            // Constraint check (§V-B): structure + resources. Only
            // the touched nodes can have changed; the full `validate`
            // runs on the finished result in every build profile
            // (`Optimizer::run`), and the `check` passes re-verify
            // pipeline outputs.
            if self.design.validate_nodes(self.model, &touched).is_err() {
                self.log.undo(&mut self.design);
                self.record_sample(kind, SaOutcome::Infeasible,
                                   prev_total);
                continue;
            }
            // Accuracy budget (quant subsystem, search mode only).
            // Execution widths can only change when some node's
            // datapath widths changed (wordlength steps narrow them;
            // combine maxes the target up; separate clones the donor,
            // and remaps always land on equal-or-wider nodes), so the
            // O(layers) SQNR proxy runs only for those candidates —
            // the ~77% of moves that touch dims/folding alone skip it.
            if let Some(floor) = self.quant_floor {
                let widths_changed =
                    self.log.saved_nodes().iter().any(|&(i, old)| {
                        let n = &self.design.nodes[i];
                        n.weight_bits != old.weight_bits
                            || n.act_bits != old.act_bits
                    });
                if widths_changed {
                    let sqnr = crate::quant::design_sqnr_db_sinks(
                        self.model, &self.design, &self.sqnr_sinks,
                        &mut self.sqnr_scratch);
                    if sqnr < floor {
                        self.log.undo(&mut self.design);
                        self.record_sample(kind, SaOutcome::Infeasible,
                                           prev_total);
                        continue;
                    }
                }
            }
            let cand_res = self.ev.price_move(&self.design, self.rm,
                                              &self.log, &touched);
            if !cand_res.fits(&self.device.avail) {
                self.ev.reject(&mut self.design, &mut self.log);
                self.record_sample(kind, SaOutcome::Infeasible,
                                   prev_total);
                continue;
            }
            let new_total = self.ev.eval_latency(
                self.model, &self.design, &self.env, &self.scfg,
                &touched);

            let accept = if new_total < prev_total {
                true
            } else {
                // Relative-delta Metropolis rule (Algorithm 2's
                // psi, normalised so tau is unitless).
                let delta =
                    (new_total - prev_total) / prev_total.max(1.0);
                self.rng.uniform()
                    < (-delta / self.tau.max(1e-12)).exp()
            };
            if accept {
                self.ev.commit();
                self.accepted_moves += 1;
                self.accepted.push((cand_res.dsp,
                                    self.ev.lat.total
                                        / self.cycles_per_ms));
                if self.ev.lat.total < self.best_lat {
                    self.best_lat = self.ev.lat.total;
                    self.best = self.design.clone();
                    self.history.push((self.iter,
                                       self.best_lat
                                           / self.cycles_per_ms));
                }
                self.record_sample(kind, SaOutcome::Accepted,
                                   new_total);
            } else {
                self.ev.reject(&mut self.design, &mut self.log);
                self.record_sample(kind, SaOutcome::Rejected,
                                   new_total);
            }
        }
        self.tau *= self.cfg.cooling;
    }

    /// Adopt another chain's best design as this chain's working
    /// design (best-so-far exchange). `latency` is the donor's
    /// recorded best latency for `design` and is used verbatim for the
    /// best-so-far bookkeeping — the locally rebuilt evaluator sums
    /// per-layer latencies in a different order than the donor's
    /// incremental accumulation, and an ulp-level mismatch must not
    /// decide whether the adoption counts as a new best. The latency
    /// memo survives the swap (entries are design-independent); the
    /// RNG stream and temperature schedule are untouched, so
    /// multi-chain runs stay deterministic regardless of thread
    /// scheduling.
    pub fn adopt(&mut self, design: &Design, latency: f64) {
        self.design = design.clone();
        let memo = std::mem::take(&mut self.ev.memo);
        self.ev = IncrementalEval::with_memo(
            self.model, &self.design, self.rm, &self.env, &self.scfg,
            memo);
        self.log = UndoLog::new();
        if latency < self.best_lat {
            self.best_lat = latency;
            self.best = self.design.clone();
            self.history.push((self.iter,
                               self.best_lat / self.cycles_per_ms));
        }
    }

    /// Snapshot of this chain's best design (uncompacted).
    pub fn best_design(&self) -> &Design {
        &self.best
    }

    /// Consume the chain into its [`OptResult`].
    pub fn finish(self) -> OptResult {
        let Chain { rm, mut best, best_lat, history, accepted, iter,
                    accepted_moves, cycles_per_ms, .. } = self;
        best.compact();
        let resources = rm.design_resources(&best);
        OptResult {
            latency_cycles: best_lat,
            latency_ms: best_lat / cycles_per_ms,
            design: best,
            resources,
            history,
            accepted,
            iterations: iter,
            accepted_moves,
        }
    }
}

/// Convenience wrapper: optimise `model` for `device`.
pub fn optimize(model: &ModelGraph, device: &Device, rm: &ResourceModel,
                cfg: OptCfg) -> Result<OptResult, String> {
    Optimizer::new(model, device, rm, cfg).run()
}

/// [`optimize`] with SA convergence telemetry recording on. The
/// returned [`OptResult`] is bit-identical to the untraced run
/// (telemetry draws no RNG — pinned by `rust/tests/obs.rs`).
pub fn optimize_traced(model: &ModelGraph, device: &Device,
                       rm: &ResourceModel, cfg: OptCfg)
    -> Result<(OptResult, SaTelemetry), String> {
    let opt = Optimizer::new(model, device, rm, cfg);
    let mut chain = Chain::new(&opt, 0)?;
    chain.enable_telemetry(0);
    while !chain.done() {
        chain.step_temp();
    }
    let tel = chain.take_telemetry().unwrap_or_default();
    let r = chain.finish();
    r.design.validate(model).map_err(|e| {
        format!("optimizer produced an invalid design: {e}")
    })?;
    Ok((r, tel))
}

/// Best-of-N restarts (SA is stochastic; the toolflow launches a small
/// portfolio of annealing runs in parallel threads and keeps the best
/// design — restarts are embarrassingly parallel).
///
/// Reproducibility contract: worker `i` anneals with the derived seed
/// `cfg.seed + i * 0x9E37` and each run is deterministic for its seed
/// (see `deterministic_for_seed`), so the whole portfolio — and
/// therefore the reported best design — is reproducible bit-for-bit
/// regardless of thread scheduling. Ties on latency resolve to the
/// lowest worker index.
pub fn optimize_multi(model: &ModelGraph, device: &Device,
                      rm: &ResourceModel, cfg: OptCfg, n_seeds: u64)
    -> Result<OptResult, String> {
    optimize_multi_obs(model, device, rm, cfg, n_seeds, false, false)
        .map(|(r, _)| r)
}

/// [`optimize_multi`] with observability hooks: when `telemetry` is
/// set, every restart records SA convergence telemetry (returned in
/// worker order, `SaTelemetry::chain` = restart index); when
/// `progress` is set, one line per finished restart goes to stderr
/// (stdout byte-pins are unaffected). Both off reproduces
/// [`optimize_multi`] exactly — same derived seeds, same tie-breaking.
pub fn optimize_multi_obs(model: &ModelGraph, device: &Device,
                          rm: &ResourceModel, cfg: OptCfg, n_seeds: u64,
                          telemetry: bool, progress: bool)
    -> Result<(OptResult, Vec<SaTelemetry>), String> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_seeds)
            .map(|i| {
                let cfg_i = OptCfg {
                    seed: cfg.seed.wrapping_add(i.wrapping_mul(0x9E37)),
                    ..cfg.clone()
                };
                scope.spawn(move || -> Result<_, String> {
                    let opt = Optimizer::new(model, device, rm, cfg_i);
                    let mut chain = Chain::new(&opt, 0)?;
                    if telemetry {
                        chain.enable_telemetry(i);
                    }
                    while !chain.done() {
                        chain.step_temp();
                    }
                    let tel = chain.take_telemetry();
                    let r = chain.finish();
                    r.design.validate(model).map_err(|e| {
                        format!("optimizer produced an invalid \
                                 design: {e}")
                    })?;
                    Ok((r, tel))
                })
            })
            .collect();
        let mut best: Option<OptResult> = None;
        let mut tels: Vec<SaTelemetry> = Vec::new();
        for (i, h) in handles.into_iter().enumerate() {
            let (r, tel) =
                h.join().map_err(|_| "SA worker panicked")??;
            if progress {
                eprintln!(
                    "[optimize] restart {}/{}: best {:.3} ms \
                     ({} accepted / {} moves)",
                    i + 1, n_seeds, r.latency_ms, r.accepted_moves,
                    r.iterations);
            }
            if let Some(t) = tel {
                tels.push(t);
            }
            best = Some(match best {
                Some(b) if b.latency_cycles <= r.latency_cycles => b,
                _ => r,
            });
        }
        let best = best.ok_or_else(|| "no seeds".to_string())?;
        Ok((best, tels))
    })
}

/// Layers eligible for fusion: Activation/Scale whose producer chain
/// bottoms out in a compute layer (conv/fc/eltwise).
pub fn fusable_layers(model: &ModelGraph) -> Vec<usize> {
    (0..model.layers.len())
        .filter(|&l| {
            matches!(model.layers[l].kind,
                     LayerKind::Activation(_) | LayerKind::Scale)
                && model.layers[l].inputs.first().is_some()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device;
    use crate::model::zoo;

    fn rm() -> ResourceModel {
        ResourceModel::fit(1, 120)
    }

    #[test]
    fn optimizes_tiny_model() {
        let m = zoo::c3d_tiny();
        let dev = device::by_name("zcu102").unwrap();
        let rm = rm();
        let r = optimize(&m, &dev, &rm, OptCfg::fast(7)).unwrap();
        assert!(r.latency_ms > 0.0);
        assert!(r.resources.fits(&dev.avail));
        assert_eq!(r.design.validate(&m), Ok(()));
        assert!(r.iterations > 100);
    }

    #[test]
    fn improves_over_warm_start() {
        let m = zoo::c3d_tiny();
        let dev = device::by_name("zcu102").unwrap();
        let rm = rm();
        let opt = Optimizer::new(&m, &dev, &rm, OptCfg::fast(7));
        let ws = opt.warm_start().unwrap();
        let env = BwEnv::of_device(&dev);
        let ws_lat = sched::total_latency_cycles(
            &m, &ws, &env, &SchedCfg::default());
        let r = opt.run().unwrap();
        assert!(r.latency_cycles <= ws_lat,
                "SA {} > warm start {}", r.latency_cycles, ws_lat);
    }

    #[test]
    fn deterministic_for_seed() {
        let m = zoo::c3d_tiny();
        let dev = device::by_name("zcu102").unwrap();
        let rm = rm();
        let a = optimize(&m, &dev, &rm, OptCfg::fast(3)).unwrap();
        let b = optimize(&m, &dev, &rm, OptCfg::fast(3)).unwrap();
        assert_eq!(a.latency_cycles, b.latency_cycles);
        assert_eq!(a.accepted_moves, b.accepted_moves);
    }

    #[test]
    fn history_is_monotone_decreasing() {
        let m = zoo::c3d_tiny();
        let dev = device::by_name("zcu102").unwrap();
        let rm = rm();
        let r = optimize(&m, &dev, &rm, OptCfg::fast(5)).unwrap();
        assert!(r
            .history
            .windows(2)
            .all(|w| w[1].1 <= w[0].1 && w[1].0 >= w[0].0));
    }

    #[test]
    fn fusion_reduces_latency() {
        let m = zoo::c3d_tiny();
        let dev = device::by_name("zcu102").unwrap();
        let rm = rm();
        let base = optimize(&m, &dev, &rm, OptCfg {
            enable_fusion: false,
            ..OptCfg::fast(9)
        })
        .unwrap();
        let fused = optimize(&m, &dev, &rm, OptCfg::fast(9)).unwrap();
        assert!(fused.latency_ms < base.latency_ms,
                "fused {} >= base {}", fused.latency_ms, base.latency_ms);
    }

    #[test]
    fn runtime_params_speedup_large() {
        // The §VII-A1 headline: runtime reconfiguration gives a large
        // boost on models whose layers span many feature-map scales —
        // shared nodes must otherwise pad everything to the maximum.
        // The paper's ablation model (R(2+1)D-18) shows 18.21x; the
        // full reproduction is in report/ablation — here we assert the
        // effect's direction and rough magnitude (>2x) on a quick run.
        let m = zoo::r2plus1d_18();
        let dev = device::by_name("zcu102").unwrap();
        let rm = rm();
        let padded = optimize(&m, &dev, &rm, OptCfg {
            runtime_params: false,
            ..OptCfg::fast(11)
        })
        .unwrap();
        let rt = optimize(&m, &dev, &rm, OptCfg::fast(11)).unwrap();
        assert!(rt.latency_ms * 2.0 < padded.latency_ms,
                "rt {} vs padded {}", rt.latency_ms, padded.latency_ms);
    }
}
