//! The transformation set of §V-C, applied as random SA moves.

use crate::model::layer::LayerKind;
use crate::model::ModelGraph;
use crate::resource::ResourceModel;
use crate::sdf::{CompNode, Design, MapTarget, NodeKind, UndoLog};
use crate::util::math::{factors, max_factor_leq};
use crate::util::rng::Rng;

use super::OptCfg;

/// Indices of nodes with at least one mapped layer.
fn used_nodes(design: &Design) -> Vec<usize> {
    let mut used = vec![false; design.nodes.len()];
    for m in &design.mapping {
        if let MapTarget::Node(i) = m {
            used[*i] = true;
        }
    }
    used.iter()
        .enumerate()
        .filter_map(|(i, &u)| if u { Some(i) } else { None })
        .collect()
}

/// The effective "channels-in" of a layer as seen by its node (FC
/// flattens the producer feature-map).
fn layer_cin(model: &ModelGraph, l: usize) -> usize {
    match model.layers[l].kind {
        LayerKind::Fc { .. } => model.layers[l].in_shape.elems(),
        _ => model.layers[l].in_shape.c,
    }
}

fn layer_filters(model: &ModelGraph, l: usize) -> usize {
    match model.layers[l].kind {
        LayerKind::Conv3d { filters, .. } | LayerKind::Fc { filters } => {
            filters
        }
        _ => model.layers[l].in_shape.c,
    }
}

/// Candidate pools for the feature-map reshaping transform (§V-C1):
/// D/W bounded by the mapped layers' maxima, H pinned to the maximum,
/// C/F drawn from the factor sets of the mapped layers' dimensions.
struct ReshapePools {
    max_d: usize,
    max_h: usize,
    max_w: usize,
    c_pool: Vec<usize>,
    f_pool: Vec<usize>,
}

fn reshape_pools(model: &ModelGraph, design: &Design, n: usize)
    -> Option<ReshapePools> {
    let layers = design.layers_of(n);
    if layers.is_empty() {
        return None;
    }
    let is_fc = design.nodes[n].kind == NodeKind::Fc;
    let (mut max_d, mut max_h, mut max_w) = (1, 1, 1);
    let mut c_pool = Vec::new();
    let mut f_pool = Vec::new();
    for &l in &layers {
        let s = model.layers[l].in_shape;
        if !is_fc {
            max_d = max_d.max(s.d);
            max_h = max_h.max(s.h);
            max_w = max_w.max(s.w);
        }
        c_pool.extend(factors(layer_cin(model, l)));
        f_pool.extend(factors(layer_filters(model, l)));
    }
    c_pool.sort_unstable();
    c_pool.dedup();
    f_pool.sort_unstable();
    f_pool.dedup();
    Some(ReshapePools { max_d, max_h, max_w, c_pool, f_pool })
}

/// Re-fix folding parameters after a dimension change so the §V-B
/// divisibility constraints keep holding.
fn refix_folding(node: &mut CompNode) {
    node.coarse_in = max_factor_leq(node.max_in.c, node.coarse_in.max(1));
    node.coarse_out =
        max_factor_leq(node.max_filters.max(1), node.coarse_out.max(1));
    if !matches!(node.kind, NodeKind::Conv | NodeKind::Fc) {
        node.coarse_out = node.coarse_in;
    }
    let k: usize = node.max_kernel.iter().product();
    node.fine = max_factor_leq(k, node.fine.max(1));
    if node.kind != NodeKind::Conv {
        node.fine = 1;
    }
}

/// Step `cur` to a neighbouring value in the sorted candidate pool
/// (one notch up or down — the factor lattice is the natural move
/// graph for the folding constraints; fully random re-sampling makes
/// the high-parallelism corner unreachable in practice).
fn step_in_pool(pool: &[usize], cur: usize, rng: &mut Rng) -> usize {
    if pool.is_empty() {
        return cur;
    }
    let pos = pool
        .iter()
        .position(|&x| x >= cur)
        .unwrap_or(pool.len() - 1);
    let up = rng.uniform() < 0.5;
    let next = if up { (pos + 1).min(pool.len() - 1) } else { pos.saturating_sub(1) };
    pool[next]
}

/// §V-C1 — Feature-Map Dimensions Reshaping (step move).
pub fn reshape(model: &ModelGraph, design: &mut Design, rng: &mut Rng,
               n: usize) -> bool {
    let Some(pools) = reshape_pools(model, design, n) else {
        return false;
    };
    let node = &mut design.nodes[n];
    if node.kind == NodeKind::Fc {
        // FC has no spatial dims; step the channel capacities only.
        node.max_in.c = step_in_pool(&pools.c_pool, node.max_in.c, rng);
        node.max_filters =
            step_in_pool(&pools.f_pool, node.max_filters, rng);
    } else {
        match rng.below(3) {
            0 => {
                let d_pool: Vec<usize> = (1..=pools.max_d).collect();
                node.max_in.d = step_in_pool(&d_pool, node.max_in.d, rng);
            }
            1 => {
                let w_pool: Vec<usize> = (1..=pools.max_w).collect();
                node.max_in.w = step_in_pool(&w_pool, node.max_in.w, rng);
            }
            _ => {
                node.max_in.c =
                    step_in_pool(&pools.c_pool, node.max_in.c, rng);
                if node.kind == NodeKind::Conv {
                    node.max_filters =
                        step_in_pool(&pools.f_pool, node.max_filters, rng);
                } else {
                    node.max_filters = node.max_in.c;
                }
            }
        }
        node.max_in.h = pools.max_h; // row dim has no resource impact
    }
    refix_folding(node);
    true
}

/// §V-C2 — Coarse-grain Folding (step move on the factor lattice).
pub fn coarse(design: &mut Design, rng: &mut Rng, n: usize) -> bool {
    let node = &mut design.nodes[n];
    let cf = factors(node.max_in.c);
    match node.kind {
        NodeKind::Conv | NodeKind::Fc => {
            if rng.uniform() < 0.5 {
                node.coarse_in = step_in_pool(&cf, node.coarse_in, rng);
            } else {
                let ff = factors(node.max_filters.max(1));
                node.coarse_out =
                    step_in_pool(&ff, node.coarse_out, rng);
            }
        }
        _ => {
            node.coarse_in = step_in_pool(&cf, node.coarse_in, rng);
            node.coarse_out = node.coarse_in;
        }
    }
    true
}

/// §V-C3 — Fine-grain Folding (conv only; step move).
pub fn fine(design: &mut Design, rng: &mut Rng, n: usize) -> bool {
    let node = &mut design.nodes[n];
    if node.kind != NodeKind::Conv {
        return false;
    }
    let k: usize = node.max_kernel.iter().product();
    node.fine = step_in_pool(&factors(k), node.fine, rng);
    true
}

/// Supported wordlengths as a step pool for [`wordlength`].
const BITS_POOL: [usize; 4] = [4, 8, 16, 32];

/// Wordlength step (quant subsystem): move one of node `n`'s datapath
/// widths one notch along {4, 8, 16, 32}. Weight width is meaningful
/// on conv/fc nodes only; other kinds step the activation width
/// alone. The caller gates the move behind `OptCfg::quant_search` and
/// the SA loop holds every candidate to the SQNR budget.
pub fn wordlength(design: &mut Design, rng: &mut Rng, n: usize) -> bool {
    let node = &mut design.nodes[n];
    let weighted = matches!(node.kind, NodeKind::Conv | NodeKind::Fc);
    if weighted && rng.uniform() < 0.5 {
        node.weight_bits =
            step_in_pool(&BITS_POOL, node.weight_bits as usize, rng)
                as u8;
    } else {
        node.act_bits =
            step_in_pool(&BITS_POOL, node.act_bits as usize, rng) as u8;
    }
    true
}

/// §V-C4 — Separate: detach `L_e` execution nodes onto fresh
/// computation nodes (one per type among the selected layers).
/// Mutations are recorded in `log` so the move can be rolled back.
pub fn separate(model: &ModelGraph, design: &mut Design, rng: &mut Rng,
                l_e: usize, log: &mut UndoLog) -> Option<Vec<usize>> {
    let mapped: Vec<usize> = design
        .mapping
        .iter()
        .enumerate()
        .filter_map(|(l, m)| match m {
            MapTarget::Node(_) => Some(l),
            _ => None,
        })
        .collect();
    if mapped.len() <= 1 {
        return None;
    }
    let mut touched = Vec::new();
    let mut new_node_of_kind: Vec<(NodeKind, usize)> = Vec::new();
    for _ in 0..l_e {
        let l = *rng.choose(&mapped);
        let MapTarget::Node(old) = design.mapping[l] else { continue };
        // Skip if the layer is alone on its node already.
        if design.layers_of(old).len() <= 1 {
            continue;
        }
        let kind = NodeKind::of_layer(&model.layers[l].kind);
        let new_idx = match new_node_of_kind
            .iter()
            .find(|(k, _)| *k == kind)
        {
            Some(&(_, i)) => i,
            None => {
                // The detached node inherits the old node's
                // compile-time parameters (the optimiser then adapts
                // them with reshape/folding moves).
                design.nodes.push(design.nodes[old].clone());
                let i = design.nodes.len() - 1;
                new_node_of_kind.push((kind, i));
                i
            }
        };
        ensure_kernel(&mut design.nodes[new_idx], &model.layers[l].kind);
        refix_folding(&mut design.nodes[new_idx]);
        log.save_mapping(design, l);
        design.mapping[l] = MapTarget::Node(new_idx);
        log.save_node(design, old);
        touched.push(old);
        touched.push(new_idx);
    }
    if touched.is_empty() {
        None
    } else {
        touched.sort_unstable();
        touched.dedup();
        // Donor nodes may now cover a smaller kernel class.
        for &n in &touched {
            fit_kernel(model, design, n);
        }
        Some(touched)
    }
}

/// §V-C4 — Combine: merge `N_c` computation nodes of one type.
/// Mutations are recorded in `log` so the move can be rolled back.
pub fn combine(model: &ModelGraph, design: &mut Design, rng: &mut Rng,
               n_c: usize, log: &mut UndoLog) -> Option<Vec<usize>> {
    let used = used_nodes(design);
    // Types with at least two used nodes.
    let mut by_kind: Vec<(NodeKind, Vec<usize>)> = Vec::new();
    for &n in &used {
        let k = design.nodes[n].kind;
        match by_kind.iter_mut().find(|(kk, _)| *kk == k) {
            Some((_, v)) => v.push(n),
            None => by_kind.push((k, vec![n])),
        }
    }
    let cands: Vec<&(NodeKind, Vec<usize>)> =
        by_kind.iter().filter(|(_, v)| v.len() >= 2).collect();
    if cands.is_empty() {
        return None;
    }
    let (_, nodes) = rng.choose(&cands);
    // Pick up to n_c distinct nodes of this type.
    let mut chosen = nodes.clone();
    while chosen.len() > n_c.max(2) {
        let i = rng.below(chosen.len());
        chosen.remove(i);
    }
    let target = chosen[0];
    log.save_node(design, target);
    for &src in &chosen[1..] {
        for l in design.layers_of(src) {
            log.save_mapping(design, l);
            design.mapping[l] = MapTarget::Node(target);
        }
        // The merged node carries the widest datapath of its members:
        // data bypasses down to narrower widths, never up (a 16-bit
        // layer cannot run on an 8-bit multiplier array). No-op at
        // the uniform 16-bit configuration.
        let (wb, ab) =
            (design.nodes[src].weight_bits, design.nodes[src].act_bits);
        let t = &mut design.nodes[target];
        t.weight_bits = t.weight_bits.max(wb);
        t.act_bits = t.act_bits.max(ab);
    }
    // Update the target to support the new set of workloads: only the
    // kernel must cover every mapped layer (runtime bypass goes down,
    // never up) — feature-map dims are handled by tiling, so keeping
    // the target's tile size avoids the line-buffer blow-up that would
    // make every merge infeasible.
    for l in design.layers_of(target) {
        ensure_kernel(&mut design.nodes[target], &model.layers[l].kind);
    }
    refix_folding(&mut design.nodes[target]);
    Some(chosen)
}

/// Recompute a node's compile-time dims as the maximum over its mapped
/// layers — the *non-runtime-parameterized* sizing rule (§III-C: the
/// hardware pads every execution up to its compile-time dimensions, so
/// those dimensions must cover every layer it serves).
pub fn fit_dims_to_max(model: &ModelGraph, design: &mut Design, n: usize) {
    let layers = design.layers_of(n);
    if layers.is_empty() {
        return;
    }
    let node = &mut design.nodes[n];
    node.max_in = crate::model::layer::Shape::new(1, 1, 1, 1);
    node.max_filters = 1;
    node.max_kernel = [1; 3];
    for l in layers {
        crate::sdf::grow_node_for_layer(node, &model.layers[l]);
    }
    refix_folding(node);
}

/// The transformation family a random move dispatched to — recorded
/// in SA convergence telemetry (`obs::SaSample`) and named on the
/// Perfetto SA tracks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MoveKind {
    Wordlength,
    Reshape,
    Coarse,
    Fine,
    Separate,
    Combine,
}

impl MoveKind {
    pub fn name(self) -> &'static str {
        match self {
            MoveKind::Wordlength => "wordlength",
            MoveKind::Reshape => "reshape",
            MoveKind::Coarse => "coarse",
            MoveKind::Fine => "fine",
            MoveKind::Separate => "separate",
            MoveKind::Combine => "combine",
        }
    }
}

/// Apply one random transformation in place, recording every mutation
/// in `log` (call `log.begin(design)` first). Returns the dispatched
/// move kind plus the touched node indices (whose mapped layers need
/// re-scheduling), or None if the move was a no-op — in which case
/// nothing was mutated.
///
/// The RNG consumption is identical for every dispatch path whether or
/// not the caller later undoes the move, which is what keeps SA runs
/// bit-identical to the historical clone-per-candidate engine.
pub fn random_move_logged_kind(model: &ModelGraph, design: &mut Design,
                               rng: &mut Rng, cfg: &OptCfg,
                               log: &mut UndoLog)
                               -> Option<(MoveKind, Vec<usize>)> {
    let used = used_nodes(design);
    if used.is_empty() {
        return None;
    }
    let roll = rng.uniform();
    let n = *rng.choose(&used);
    // Wordlength moves (quant subsystem) take the top 12.5% of the
    // roll when the search is enabled; the remainder is renormalised
    // so the historical dispatch keeps its exact proportions — and,
    // with the search off, its exact RNG stream (the bit-identical
    // trace contract of the 16-bit configuration).
    let roll = if cfg.quant_search() {
        if roll >= 0.875 {
            log.save_node(design, n);
            return wordlength(design, rng, n)
                .then(|| (MoveKind::Wordlength, vec![n]));
        }
        roll / 0.875
    } else {
        roll
    };
    if !cfg.runtime_params {
        // Baseline hardware cannot tile below its compile-time dims:
        // feature-map reshaping is unavailable, and combination /
        // separation must re-size nodes to the max of their layers.
        let (kind, touched) = if roll < 0.45 {
            log.save_node(design, n);
            (MoveKind::Coarse, coarse(design, rng, n).then(|| vec![n]))
        } else if roll < 0.60 {
            log.save_node(design, n);
            (MoveKind::Fine, fine(design, rng, n).then(|| vec![n]))
        } else if cfg.enable_combine && roll < 0.80 {
            (MoveKind::Separate, separate(model, design, rng, cfg.l_e, log))
        } else if cfg.enable_combine {
            (MoveKind::Combine, combine(model, design, rng, cfg.n_c, log))
        } else {
            log.save_node(design, n);
            (MoveKind::Coarse, coarse(design, rng, n).then(|| vec![n]))
        };
        if let Some(ts) = &touched {
            for &t in ts {
                log.save_node(design, t);
                fit_dims_to_max(model, design, t);
            }
        }
        return touched.map(|t| (kind, t));
    }
    if roll < 0.30 {
        log.save_node(design, n);
        reshape(model, design, rng, n)
            .then(|| (MoveKind::Reshape, vec![n]))
    } else if roll < 0.60 {
        log.save_node(design, n);
        coarse(design, rng, n).then(|| (MoveKind::Coarse, vec![n]))
    } else if roll < 0.75 {
        log.save_node(design, n);
        fine(design, rng, n).then(|| (MoveKind::Fine, vec![n]))
    } else if cfg.enable_combine && roll < 0.875 {
        separate(model, design, rng, cfg.l_e, log)
            .map(|t| (MoveKind::Separate, t))
    } else if cfg.enable_combine {
        combine(model, design, rng, cfg.n_c, log)
            .map(|t| (MoveKind::Combine, t))
    } else {
        // Combine/separate disabled: fall back to a folding move.
        log.save_node(design, n);
        coarse(design, rng, n).then(|| (MoveKind::Coarse, vec![n]))
    }
}

/// [`random_move_logged_kind`] without the kind tag, for callers that
/// don't record telemetry.
pub fn random_move_logged(model: &ModelGraph, design: &mut Design,
                          rng: &mut Rng, cfg: &OptCfg,
                          log: &mut UndoLog) -> Option<Vec<usize>> {
    random_move_logged_kind(model, design, rng, cfg, log)
        .map(|(_, t)| t)
}

/// Apply one random transformation; returns the touched node indices
/// (whose mapped layers need re-scheduling), or None if the move was a
/// no-op. Convenience wrapper over [`random_move_logged`] for callers
/// that never roll back (tests, one-shot design surgery).
pub fn random_move(model: &ModelGraph, design: &mut Design, rng: &mut Rng,
                   cfg: &OptCfg) -> Option<Vec<usize>> {
    let mut log = UndoLog::new();
    log.begin(design);
    random_move_logged(model, design, rng, cfg, &mut log)
}

/// Grow a node's kernel capacity to cover a layer's kernel.
fn ensure_kernel(node: &mut CompNode, kind: &LayerKind) {
    if let LayerKind::Conv3d { kernel, .. }
    | LayerKind::Pool3d { kernel, .. } = kind
    {
        for d in 0..3 {
            node.max_kernel[d] = node.max_kernel[d].max(kernel[d]);
        }
    }
}

/// Shrink a node's kernel capacity to exactly cover its mapped layers
/// (called after separation — losing the 7x7 stem lets the node drop
/// back to 3-deep line buffers).
fn fit_kernel(model: &ModelGraph, design: &mut Design, n: usize) {
    if !matches!(design.nodes[n].kind, NodeKind::Conv | NodeKind::Pool) {
        return;
    }
    let mut k = [1usize; 3];
    for l in design.layers_of(n) {
        if let LayerKind::Conv3d { kernel, .. }
        | LayerKind::Pool3d { kernel, .. } = &model.layers[l].kind
        {
            for d in 0..3 {
                k[d] = k[d].max(kernel[d]);
            }
        }
    }
    design.nodes[n].max_kernel = k;
    refix_folding(&mut design.nodes[n]);
}

/// Fuse every eligible Activation/Scale layer into its producer
/// (applied once at initialisation when fusion is enabled).
pub fn fuse_all(model: &ModelGraph, design: &mut Design) {
    for (l, layer) in model.layers.iter().enumerate() {
        if !matches!(layer.kind,
                     LayerKind::Activation(_) | LayerKind::Scale) {
            continue;
        }
        let Some(&src) = layer.inputs.first() else { continue };
        let producer_ok = matches!(
            model.layers[src].kind,
            LayerKind::Conv3d { .. }
                | LayerKind::Fc { .. }
                | LayerKind::Eltwise { .. }
                | LayerKind::Scale
        );
        if producer_ok {
            design.mapping[l] = MapTarget::Fused;
        }
    }
}

/// Shrink the node with the largest non-DSP footprint one notch —
/// used by the warm start until the design fits the device.
pub fn shrink_largest(model: &ModelGraph, design: &mut Design,
                      rm: &ResourceModel) {
    let used = used_nodes(design);
    let heaviest = used
        .iter()
        .copied()
        .max_by(|&a, &b| {
            let ra = rm.node_resources(&design.nodes[a]);
            let rb = rm.node_resources(&design.nodes[b]);
            (ra.bram + ra.lut / 100.0)
                .total_cmp(&(rb.bram + rb.lut / 100.0))
        });
    let Some(n) = heaviest else { return };
    let node = &mut design.nodes[n];
    // Step down the dominant dimension.
    if node.max_in.c > 1 && node.max_in.c >= node.max_in.w {
        let fs = factors_below(node.max_in.c);
        node.max_in.c = fs;
        if !matches!(node.kind, NodeKind::Conv | NodeKind::Fc) {
            node.max_filters = node.max_in.c;
        }
    } else if node.max_in.w > 1 {
        node.max_in.w = node.max_in.w.div_ceil(2);
    } else if node.max_in.d > 1 {
        node.max_in.d = node.max_in.d.div_ceil(2);
    } else if node.max_filters > 1 {
        node.max_filters = factors_below(node.max_filters);
    } else if node.coarse_in > 1 || node.coarse_out > 1 || node.fine > 1 {
        node.coarse_in = 1;
        node.coarse_out = 1;
        node.fine = 1;
    } else if node.max_in.h > 1 {
        // Last resort: the paper keeps H at the max, but feasibility
        // wins over the heuristic.
        node.max_in.h = node.max_in.h.div_ceil(2);
    }
    refix_folding(node);
    let _ = model;
}

/// Largest proper divisor step-down helper: next value below `x`
/// halving-ish while keeping "nice" channel counts.
fn factors_below(x: usize) -> usize {
    (x / 2).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn moves_preserve_validity() {
        let m = zoo::r2plus1d_18();
        let mut d = Design::initial(&m);
        let mut rng = Rng::new(42);
        let cfg = OptCfg::default();
        let mut applied = 0;
        for _ in 0..500 {
            let mut cand = d.clone();
            if random_move(&m, &mut cand, &mut rng, &cfg).is_some()
                && cand.validate(&m).is_ok()
            {
                d = cand;
                applied += 1;
            }
        }
        assert!(applied > 300, "only {applied} moves applied");
        assert_eq!(d.validate(&m), Ok(()));
    }

    #[test]
    fn separate_then_combine_roundtrip_validity() {
        let m = zoo::c3d();
        let mut d = Design::initial(&m);
        let mut rng = Rng::new(1);
        let mut log = UndoLog::new();
        for _ in 0..50 {
            log.begin(&d);
            separate(&m, &mut d, &mut rng, 2, &mut log);
            assert_eq!(d.validate(&m), Ok(()));
        }
        for _ in 0..50 {
            log.begin(&d);
            combine(&m, &mut d, &mut rng, 2, &mut log);
            assert_eq!(d.validate(&m), Ok(()));
        }
        d.compact();
        assert_eq!(d.validate(&m), Ok(()));
    }

    #[test]
    fn logged_moves_undo_exactly() {
        // Every §V-C move must be fully reversible from its undo log:
        // nodes, node count, and mapping all restored bit-for-bit.
        let m = zoo::r2plus1d_18();
        let mut d = Design::initial(&m);
        let mut rng = Rng::new(0xBEEF);
        let cfg = OptCfg::default();
        let mut log = UndoLog::new();
        let mut applied = 0;
        for step in 0..400 {
            let before = d.clone();
            log.begin(&d);
            let moved =
                random_move_logged(&m, &mut d, &mut rng, &cfg, &mut log);
            if moved.is_some() {
                applied += 1;
            }
            // Undo every move (applied or no-op) and compare.
            log.undo(&mut d);
            assert_eq!(d.nodes, before.nodes, "step {step}");
            assert_eq!(d.mapping, before.mapping, "step {step}");
            // Re-apply some moves so later steps see varied designs.
            if step % 3 == 0 {
                log.begin(&d);
                if random_move_logged(&m, &mut d, &mut rng, &cfg,
                                      &mut log).is_none()
                    || d.validate(&m).is_err()
                {
                    log.undo(&mut d);
                }
            }
        }
        assert!(applied > 200, "only {applied} moves applied");
    }

    #[test]
    fn wordlength_steps_stay_in_pool_and_undo_exactly() {
        let m = zoo::c3d();
        let mut d = Design::initial(&m);
        let mut rng = Rng::new(9);
        let mut log = UndoLog::new();
        let mut changed = 0;
        for _ in 0..200 {
            let n = rng.below(d.nodes.len());
            let before = d.clone();
            log.begin(&d);
            log.save_node(&d, n);
            wordlength(&mut d, &mut rng, n);
            assert!(crate::quant::is_wordlength(d.nodes[n].weight_bits));
            assert!(crate::quant::is_wordlength(d.nodes[n].act_bits));
            assert_eq!(d.validate(&m), Ok(()));
            if d.nodes[n] != before.nodes[n] {
                changed += 1;
            }
            if rng.below(2) == 0 {
                log.undo(&mut d);
                assert_eq!(d.nodes, before.nodes);
            }
        }
        assert!(changed > 50, "only {changed} width changes");
    }

    #[test]
    fn quant_search_gates_the_wordlength_move() {
        // With the search off the dispatch never touches widths (the
        // bit-identity contract); with it on, widths move.
        let m = zoo::c3d();
        let all_16 = |d: &Design| {
            d.nodes
                .iter()
                .all(|n| n.weight_bits == 16 && n.act_bits == 16)
        };
        let run = |search: bool| {
            let mut d = Design::initial(&m);
            let mut rng = Rng::new(0xA11);
            let cfg = OptCfg {
                quant: Some(crate::quant::QuantCfg {
                    search,
                    ..crate::quant::QuantCfg::default()
                }),
                ..OptCfg::default()
            };
            for _ in 0..300 {
                let mut cand = d.clone();
                if random_move(&m, &mut cand, &mut rng, &cfg).is_some()
                    && cand.validate(&m).is_ok()
                {
                    d = cand;
                }
            }
            d
        };
        assert!(all_16(&run(false)));
        assert!(!all_16(&run(true)));
    }

    #[test]
    fn fuse_all_fuses_relus() {
        let m = zoo::c3d();
        let mut d = Design::initial(&m);
        fuse_all(&m, &mut d);
        let fused = d
            .mapping
            .iter()
            .filter(|m| matches!(m, MapTarget::Fused))
            .count();
        // 8 conv relus + 2 fc relus + softmax (producer fc8) = 11.
        assert_eq!(fused, 11);
        assert_eq!(d.validate(&m), Ok(()));
    }

    #[test]
    fn shrink_reduces_footprint() {
        let m = zoo::c3d();
        let mut d = Design::initial(&m);
        let rm = ResourceModel::fit(1, 100);
        let before = rm.design_resources(&d);
        for _ in 0..10 {
            shrink_largest(&m, &mut d, &rm);
        }
        let after = rm.design_resources(&d);
        assert!(after.bram < before.bram || after.lut < before.lut);
        assert_eq!(d.validate(&m), Ok(()));
    }

    #[test]
    fn reshape_keeps_h_at_max() {
        let m = zoo::c3d();
        let mut d = Design::initial(&m);
        let mut rng = Rng::new(5);
        let conv = d
            .nodes
            .iter()
            .position(|n| n.kind == NodeKind::Conv)
            .unwrap();
        for _ in 0..20 {
            reshape(&m, &mut d, &mut rng, conv);
            assert_eq!(d.nodes[conv].max_in.h, 112);
            assert_eq!(d.validate(&m), Ok(()));
        }
    }
}
