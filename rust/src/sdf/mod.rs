//! Synchronous Data-Flow layer: computation (hardware) nodes, the
//! hardware graph `G`, the execution mapping `E : G -> P(M)`, and the
//! runtime parameter tuples Γ (§III).
//!
//! A `Design` is one point in the search space: a set of computation
//! nodes with compile-time parameters (Table I) plus the mapping from
//! every model execution node onto a computation node (or into its
//! producer, when the activation-fusion optimisation applies).

use crate::model::layer::{LayerKind, Shape};
use crate::model::ModelGraph;

/// Building-block type of a computation node. Execution nodes may only
/// map onto a node of their own type (§V-C4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NodeKind {
    Conv,
    Pool,
    Act,
    Eltwise,
    Gap,
    Fc,
}

impl NodeKind {
    pub fn of_layer(kind: &LayerKind) -> NodeKind {
        match kind {
            LayerKind::Conv3d { .. } => NodeKind::Conv,
            LayerKind::Pool3d { .. } => NodeKind::Pool,
            LayerKind::Activation(_) => NodeKind::Act,
            LayerKind::Eltwise { .. } | LayerKind::Scale
            | LayerKind::Concat => NodeKind::Eltwise,
            LayerKind::GlobalAvgPool => NodeKind::Gap,
            LayerKind::Fc { .. } => NodeKind::Fc,
        }
    }

    pub fn tag(&self) -> &'static str {
        match self {
            NodeKind::Conv => "conv",
            NodeKind::Pool => "pool",
            NodeKind::Act => "act",
            NodeKind::Eltwise => "eltwise",
            NodeKind::Gap => "gap",
            NodeKind::Fc => "fc",
        }
    }

    /// Inverse of [`NodeKind::tag`] (the serialized-design kind key).
    pub fn parse_tag(tag: &str) -> Option<NodeKind> {
        match tag {
            "conv" => Some(NodeKind::Conv),
            "pool" => Some(NodeKind::Pool),
            "act" => Some(NodeKind::Act),
            "eltwise" => Some(NodeKind::Eltwise),
            "gap" => Some(NodeKind::Gap),
            "fc" => Some(NodeKind::Fc),
            _ => None,
        }
    }
}

/// A computation node `n` of the hardware graph `G` with its
/// compile-time parameters (Table I).
///
/// `Copy + Eq + Hash` because the node's parameter tuple *is* its
/// identity for the SA engine's caches: the latency memo keys on
/// `(layer, CompNode)` and the undo log snapshots whole nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CompNode {
    pub kind: NodeKind,
    /// Maximum supported input feature-map tile `S_n^in`;
    /// `max_in.c` is the channel capacity `C_n`.
    pub max_in: Shape,
    /// `F_n` — filter capacity (conv/fc only; mirrors `max_in.c`
    /// otherwise).
    pub max_filters: usize,
    /// `K_n` — maximum kernel extent (D, H, W); conv/pool only.
    pub max_kernel: [usize; 3],
    /// `c_n^in` — parallel streams in (must divide `max_in.c`).
    pub coarse_in: usize,
    /// `c_n^out` — parallel streams out (must divide `max_filters`).
    pub coarse_out: usize,
    /// `f_n` — vector dot-product folding (must divide `|K_n|`).
    pub fine: usize,
    /// Weight datapath wordlength in bits (quant subsystem; one of
    /// `quant::WORDLENGTHS`). Sizes the weight buffers and the
    /// multiplier operand width; 16 is the paper's fixed datapath.
    pub weight_bits: u8,
    /// Activation/feature-map wordlength in bits: sizes line buffers,
    /// stream widths, and the DMA word traffic.
    pub act_bits: u8,
}

impl CompNode {
    /// DSPs consumed (§IV-B): only Conv and FC use DSPs. At <= 8-bit
    /// operands two multiplies pack into one DSP48
    /// ([`CompNode::dsp_packing`]); the 16-bit datapath is exactly the
    /// paper's one-multiplier-per-DSP count.
    pub fn dsp(&self) -> f64 {
        match self.kind {
            NodeKind::Conv => {
                (self.coarse_in * self.coarse_out * self.fine)
                    .div_ceil(self.dsp_packing()) as f64
            }
            NodeKind::Fc => (self.coarse_in * self.coarse_out)
                .div_ceil(self.dsp_packing()) as f64,
            _ => 0.0,
        }
    }

    /// Hardware multipliers instantiated (the LUT/FF size driver —
    /// DSP *slices* may pack two of them, multiplier count does not
    /// change with packing).
    pub fn mults(&self) -> f64 {
        match self.kind {
            NodeKind::Conv => {
                (self.coarse_in * self.coarse_out * self.fine) as f64
            }
            NodeKind::Fc => (self.coarse_in * self.coarse_out) as f64,
            _ => 0.0,
        }
    }

    /// Multiplies per DSP48 slice: two when both operands fit 8 bits
    /// (the INT8 packing every recent quantised accelerator leans on),
    /// one otherwise.
    pub fn dsp_packing(&self) -> usize {
        if self.weight_bits <= 8 && self.act_bits <= 8 { 2 } else { 1 }
    }

    /// Datapath-width scale for the LUT/FF models: fabric cost of
    /// multipliers/adders/muxes grows ~linearly with operand width.
    /// Exactly 1.0 at the 16-bit datapath the regression set is
    /// calibrated on.
    pub fn width_scale(&self) -> f64 {
        match self.kind {
            NodeKind::Conv | NodeKind::Fc => {
                (self.weight_bits as f64 + self.act_bits as f64) / 32.0
            }
            _ => self.act_bits as f64 / 16.0,
        }
    }
}

/// Where an execution node runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapTarget {
    /// Index into `Design::nodes`.
    Node(usize),
    /// Fused into its producer (activation-fusion optimisation,
    /// §VII-A1) — costs nothing on the schedule.
    Fused,
}

/// One point of the design space: hardware graph + execution mapping.
#[derive(Debug, Clone)]
pub struct Design {
    pub nodes: Vec<CompNode>,
    /// `mapping[l]` — the computation node executing model layer `l`.
    pub mapping: Vec<MapTarget>,
}

impl Design {
    /// The initial design of §V-C4: execution nodes combined onto one
    /// computation node per (type, kernel-class), sized to the
    /// *maximum* requirement of its mapped layers (the "warm start" —
    /// feasible w.r.t. schedulability, minimal parallelism).
    ///
    /// Grouping by kernel class (not type alone) keeps a lone 7x7
    /// stem from forcing 7-deep line buffers onto the node that
    /// executes every 3x3x3 layer — the runtime kernel crossbar
    /// bypasses *down* from the compile-time maximum, never up.
    pub fn initial(model: &ModelGraph) -> Design {
        let mut nodes: Vec<CompNode> = Vec::new();
        let mut node_of: Vec<((NodeKind, [usize; 3]), usize)> = Vec::new();
        let mut mapping = Vec::with_capacity(model.layers.len());
        for layer in &model.layers {
            let kind = NodeKind::of_layer(&layer.kind);
            let kclass = layer_kernel(&layer.kind).unwrap_or([1; 3]);
            let key = (kind, kclass);
            let idx = match node_of.iter().find(|(k, _)| *k == key) {
                Some(&(_, i)) => i,
                None => {
                    nodes.push(CompNode {
                        kind,
                        max_in: Shape::new(1, 1, 1, 1),
                        max_filters: 1,
                        max_kernel: [1; 3],
                        coarse_in: 1,
                        coarse_out: 1,
                        fine: 1,
                        weight_bits: 16,
                        act_bits: 16,
                    });
                    node_of.push((key, nodes.len() - 1));
                    nodes.len() - 1
                }
            };
            grow_node_for_layer(&mut nodes[idx], layer);
            mapping.push(MapTarget::Node(idx));
        }
        Design { nodes, mapping }
    }

    /// The pre-combination mapping of §V-C4: one unique computation
    /// node per execution node. This is the §VII-A1 ablation baseline
    /// (with the combination transform disabled there is nothing to
    /// share, so runtime parameterisation is moot: every node exactly
    /// fits its layer).
    pub fn initial_per_layer(model: &ModelGraph) -> Design {
        let mut nodes = Vec::with_capacity(model.layers.len());
        let mut mapping = Vec::with_capacity(model.layers.len());
        for layer in &model.layers {
            let mut node = CompNode {
                kind: NodeKind::of_layer(&layer.kind),
                max_in: Shape::new(1, 1, 1, 1),
                max_filters: 1,
                max_kernel: [1; 3],
                coarse_in: 1,
                coarse_out: 1,
                fine: 1,
                weight_bits: 16,
                act_bits: 16,
            };
            grow_node_for_layer(&mut node, layer);
            nodes.push(node);
            mapping.push(MapTarget::Node(nodes.len() - 1));
        }
        Design { nodes, mapping }
    }

    /// Layers mapped to node `n` — the inverse mapping `E(n)`.
    pub fn layers_of(&self, n: usize) -> Vec<usize> {
        self.mapping
            .iter()
            .enumerate()
            .filter_map(|(l, m)| match m {
                MapTarget::Node(i) if *i == n => Some(l),
                _ => None,
            })
            .collect()
    }

    /// Validate structural invariants: disjoint mapping is implied by
    /// the `Vec` representation; check node indices, kind agreement,
    /// fusion legality, and compile-time parameter divisibility.
    pub fn validate(&self, model: &ModelGraph) -> Result<(), String> {
        if self.mapping.len() != model.layers.len() {
            return Err("mapping arity mismatch".into());
        }
        for (l, m) in self.mapping.iter().enumerate() {
            let layer = &model.layers[l];
            match m {
                MapTarget::Node(i) => {
                    let node = self
                        .nodes
                        .get(*i)
                        .ok_or(format!("layer {l}: bad node index"))?;
                    if node.kind != NodeKind::of_layer(&layer.kind) {
                        return Err(format!(
                            "layer {l} ({}) mapped to {:?} node",
                            layer.name, node.kind
                        ));
                    }
                }
                MapTarget::Fused => {
                    if !matches!(layer.kind,
                                 LayerKind::Activation(_) | LayerKind::Scale)
                    {
                        return Err(format!(
                            "layer {l} ({}) cannot fuse: not activation",
                            layer.name
                        ));
                    }
                    let Some(&src) = layer.inputs.first() else {
                        return Err(format!("layer {l}: fused model input"));
                    };
                    let pk = &model.layers[src].kind;
                    let fusable = matches!(
                        pk,
                        LayerKind::Conv3d { .. }
                            | LayerKind::Fc { .. }
                            | LayerKind::Eltwise { .. }
                            | LayerKind::Scale
                    );
                    if !fusable || self.mapping[src] == MapTarget::Fused
                        && !matches!(pk, LayerKind::Scale | LayerKind::Eltwise {..})
                    {
                        // A fused producer chain is fine as long as the
                        // chain bottoms out in a compute layer.
                    }
                    if !fusable {
                        return Err(format!(
                            "layer {l} ({}) fused into non-compute producer",
                            layer.name
                        ));
                    }
                }
            }
        }
        for (i, node) in self.nodes.iter().enumerate() {
            if node.max_in.c % node.coarse_in != 0 {
                return Err(format!("node {i}: c_in !| C_n"));
            }
            if node.max_filters % node.coarse_out != 0 {
                return Err(format!("node {i}: c_out !| F_n"));
            }
            let k: usize = node.max_kernel.iter().product();
            if k % node.fine != 0 {
                return Err(format!("node {i}: f !| |K_n|"));
            }
            if !crate::quant::is_wordlength(node.weight_bits)
                || !crate::quant::is_wordlength(node.act_bits)
            {
                return Err(format!("node {i}: unsupported wordlength"));
            }
        }
        // Every node must be able to *schedule* its layers: kernel
        // coverage (runtime-parameterized nodes bypass down, never up).
        for (l, m) in self.mapping.iter().enumerate() {
            if let MapTarget::Node(i) = m {
                let node = &self.nodes[*i];
                if let Some(k) = layer_kernel(&model.layers[l].kind) {
                    for d in 0..3 {
                        if k[d] > node.max_kernel[d] {
                            return Err(format!(
                                "layer {l}: kernel exceeds node {i}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Constraint check restricted to `nodes` (the SA hot path: a move
    /// touches one or two nodes, and the untouched remainder of the
    /// design was valid before the move). Checks the same §V-B
    /// invariants as `validate` for the touched subset.
    pub fn validate_nodes(&self, model: &ModelGraph, nodes: &[usize])
        -> Result<(), String> {
        for &i in nodes {
            let Some(node) = self.nodes.get(i) else {
                return Err(format!("bad node index {i}"));
            };
            if node.max_in.c % node.coarse_in != 0 {
                return Err(format!("node {i}: c_in !| C_n"));
            }
            if node.max_filters % node.coarse_out != 0 {
                return Err(format!("node {i}: c_out !| F_n"));
            }
            let k: usize = node.max_kernel.iter().product();
            if k % node.fine != 0 {
                return Err(format!("node {i}: f !| |K_n|"));
            }
            if !crate::quant::is_wordlength(node.weight_bits)
                || !crate::quant::is_wordlength(node.act_bits)
            {
                return Err(format!("node {i}: unsupported wordlength"));
            }
        }
        for (l, m) in self.mapping.iter().enumerate() {
            if let MapTarget::Node(i) = m {
                if !nodes.contains(i) {
                    continue;
                }
                let node = &self.nodes[*i];
                if node.kind != NodeKind::of_layer(&model.layers[l].kind) {
                    return Err(format!("layer {l}: kind mismatch"));
                }
                if let Some(k) = layer_kernel(&model.layers[l].kind) {
                    for d in 0..3 {
                        if k[d] > node.max_kernel[d] {
                            return Err(format!(
                                "layer {l}: kernel exceeds node {i}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Number of *used* computation nodes (some may lose all layers
    /// after combine moves; they are garbage-collected by `compact`).
    pub fn used_nodes(&self) -> usize {
        let mut used = vec![false; self.nodes.len()];
        for m in &self.mapping {
            if let MapTarget::Node(i) = m {
                used[*i] = true;
            }
        }
        used.iter().filter(|&&u| u).count()
    }

    /// Drop unused nodes and remap indices.
    pub fn compact(&mut self) {
        let mut used = vec![false; self.nodes.len()];
        for m in &self.mapping {
            if let MapTarget::Node(i) = m {
                used[*i] = true;
            }
        }
        let mut remap = vec![usize::MAX; self.nodes.len()];
        let mut nodes = Vec::new();
        for (i, node) in self.nodes.iter().enumerate() {
            if used[i] {
                remap[i] = nodes.len();
                nodes.push(node.clone());
            }
        }
        for m in &mut self.mapping {
            if let MapTarget::Node(i) = m {
                *i = remap[*i];
            }
        }
        self.nodes = nodes;
    }

    /// Serialize to the deterministic design-JSON the `check
    /// --design` / `optimize --design-out` round trip uses:
    /// `{"mapping": [0, "fused", ...], "nodes": [{...}, ...]}` with
    /// alphabetical keys (the `Json` BTreeMap representation).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let nodes = self.nodes.iter().map(|n| Json::obj(vec![
            ("act_bits", Json::Num(n.act_bits as f64)),
            ("coarse_in", Json::Num(n.coarse_in as f64)),
            ("coarse_out", Json::Num(n.coarse_out as f64)),
            ("fine", Json::Num(n.fine as f64)),
            ("kind", Json::Str(n.kind.tag().to_string())),
            ("max_filters", Json::Num(n.max_filters as f64)),
            ("max_in", Json::from_usizes(
                &[n.max_in.d, n.max_in.h, n.max_in.w, n.max_in.c])),
            ("max_kernel", Json::from_usizes(&n.max_kernel)),
            ("weight_bits", Json::Num(n.weight_bits as f64)),
        ])).collect();
        let mapping = self.mapping.iter().map(|m| match m {
            MapTarget::Node(i) => Json::Num(*i as f64),
            MapTarget::Fused => Json::Str("fused".to_string()),
        }).collect();
        Json::obj(vec![
            ("mapping", Json::Arr(mapping)),
            ("nodes", Json::Arr(nodes)),
        ])
    }

    /// Parse the design-JSON emitted by [`Design::to_json`]. Only the
    /// *shape* of the document is checked here (`"design: ..."`
    /// errors); semantic legality against a model is the `check`
    /// passes' job.
    pub fn from_json(j: &crate::util::json::Json)
        -> Result<Design, String> {
        use crate::util::json::Json;
        let nodes_j = j.get("nodes").and_then(Json::as_arr)
            .ok_or("design: missing \"nodes\" array")?;
        let mut nodes = Vec::with_capacity(nodes_j.len());
        for (i, nj) in nodes_j.iter().enumerate() {
            let field = |k: &str| nj.get(k).and_then(Json::as_usize)
                .ok_or(format!("design: node {i}: missing numeric \
                                field {k:?}"));
            let kind = nj.get("kind").and_then(Json::as_str)
                .and_then(NodeKind::parse_tag)
                .ok_or(format!("design: node {i}: bad \"kind\" tag"))?;
            let s = nj.get("max_in").and_then(Json::usize_arr)
                .filter(|v| v.len() == 4)
                .ok_or(format!("design: node {i}: \"max_in\" must be \
                                a 4-element array"))?;
            let k = nj.get("max_kernel").and_then(Json::usize_arr)
                .filter(|v| v.len() == 3)
                .ok_or(format!("design: node {i}: \"max_kernel\" must \
                                be a 3-element array"))?;
            let bits = |k: &str| -> Result<u8, String> {
                let v = field(k)?;
                u8::try_from(v).map_err(|_| format!(
                    "design: node {i}: {k:?} {v} does not fit u8"))
            };
            nodes.push(CompNode {
                kind,
                max_in: Shape::new(s[0], s[1], s[2], s[3]),
                max_filters: field("max_filters")?,
                max_kernel: [k[0], k[1], k[2]],
                coarse_in: field("coarse_in")?,
                coarse_out: field("coarse_out")?,
                fine: field("fine")?,
                weight_bits: bits("weight_bits")?,
                act_bits: bits("act_bits")?,
            });
        }
        let mapping_j = j.get("mapping").and_then(Json::as_arr)
            .ok_or("design: missing \"mapping\" array")?;
        let mut mapping = Vec::with_capacity(mapping_j.len());
        for (l, mj) in mapping_j.iter().enumerate() {
            match (mj.as_usize(), mj.as_str()) {
                (Some(i), _) => mapping.push(MapTarget::Node(i)),
                (None, Some("fused")) => mapping.push(MapTarget::Fused),
                _ => return Err(format!(
                    "design: mapping entry {l} must be a node index \
                     or \"fused\"")),
            }
        }
        Ok(Design { nodes, mapping })
    }
}

/// Undo record for one SA move (§V-C transforms applied in place).
///
/// The clone-per-candidate engine copied the whole `Design` (nodes +
/// mapping) for every proposed move; a move only ever touches 1–2
/// nodes and a handful of mapping entries, so the undo log records
/// exactly those: pre-move snapshots of mutated nodes, pre-move
/// mapping targets of remapped layers, and the node count (separation
/// pushes fresh nodes, which `undo` truncates away). `undo` restores
/// the design bit-for-bit, which is what keeps the in-place engine's
/// accepted-move sequence identical to the clone-based one.
#[derive(Debug, Default)]
pub struct UndoLog {
    old_nodes_len: usize,
    nodes: Vec<(usize, CompNode)>,
    mapping: Vec<(usize, MapTarget)>,
}

impl UndoLog {
    pub fn new() -> UndoLog {
        UndoLog::default()
    }

    /// Start recording a move against the current design state.
    pub fn begin(&mut self, design: &Design) {
        self.old_nodes_len = design.nodes.len();
        self.nodes.clear();
        self.mapping.clear();
    }

    /// Snapshot node `i` before mutating it. First write wins, so the
    /// snapshot is always the pre-move state; nodes pushed after
    /// `begin` need no snapshot (undo truncates them).
    pub fn save_node(&mut self, design: &Design, i: usize) {
        if i >= self.old_nodes_len
            || self.nodes.iter().any(|&(j, _)| j == i)
        {
            return;
        }
        self.nodes.push((i, design.nodes[i]));
    }

    /// Snapshot layer `l`'s mapping target before reassigning it.
    pub fn save_mapping(&mut self, design: &Design, l: usize) {
        if self.mapping.iter().any(|&(j, _)| j == l) {
            return;
        }
        self.mapping.push((l, design.mapping[l]));
    }

    /// Pre-move mapping targets of every remapped layer (each layer at
    /// most once) — consumed by the optimiser's reverse index.
    pub fn mapping_edits(&self) -> &[(usize, MapTarget)] {
        &self.mapping
    }

    /// Pre-move snapshots of every mutated node (each node at most
    /// once) — lets the optimiser detect cheaply *what kind* of state
    /// a move touched (e.g. whether any datapath width changed, which
    /// is the only way a move can affect the quant SQNR proxy).
    pub fn saved_nodes(&self) -> &[(usize, CompNode)] {
        &self.nodes
    }

    /// Node count at `begin` time.
    pub fn old_nodes_len(&self) -> usize {
        self.old_nodes_len
    }

    /// Roll the design back to its state at `begin`, clearing the log.
    pub fn undo(&mut self, design: &mut Design) {
        for &(l, m) in &self.mapping {
            design.mapping[l] = m;
        }
        for &(i, node) in &self.nodes {
            design.nodes[i] = node;
        }
        design.nodes.truncate(self.old_nodes_len);
        self.nodes.clear();
        self.mapping.clear();
    }
}

/// Kernel extent of a layer, if it has one.
pub fn layer_kernel(kind: &LayerKind) -> Option<[usize; 3]> {
    match kind {
        LayerKind::Conv3d { kernel, .. }
        | LayerKind::Pool3d { kernel, .. } => Some(*kernel),
        _ => None,
    }
}

/// Grow a node's compile-time parameters so `layer` becomes
/// schedulable on it (used by the warm start and the combine move).
pub fn grow_node_for_layer(node: &mut CompNode,
                           layer: &crate::model::Layer) {
    let s = layer.in_shape;
    node.max_in.d = node.max_in.d.max(s.d);
    node.max_in.h = node.max_in.h.max(s.h);
    node.max_in.w = node.max_in.w.max(s.w);
    node.max_in.c = node.max_in.c.max(s.c);
    match &layer.kind {
        LayerKind::Conv3d { filters, kernel, .. } => {
            node.max_filters = node.max_filters.max(*filters);
            for d in 0..3 {
                node.max_kernel[d] = node.max_kernel[d].max(kernel[d]);
            }
        }
        LayerKind::Fc { filters } => {
            node.max_in.c = node.max_in.c.max(s.elems());
            node.max_filters = node.max_filters.max(*filters);
        }
        LayerKind::Pool3d { kernel, .. } => {
            for d in 0..3 {
                node.max_kernel[d] = node.max_kernel[d].max(kernel[d]);
            }
            node.max_filters = node.max_in.c;
        }
        _ => {
            node.max_filters = node.max_in.c;
        }
    }
    // Keep divisibility invariants after growth.
    if node.max_in.c % node.coarse_in != 0 {
        node.coarse_in = 1;
    }
    if node.max_filters % node.coarse_out != 0 {
        node.coarse_out = 1;
    }
    let k: usize = node.max_kernel.iter().product();
    if k % node.fine != 0 {
        node.fine = 1;
    }
}

/// The runtime parameter tuple Γ for one invocation of a computation
/// node — one schedule entry (Algorithm 1 output).
#[derive(Debug, Clone, PartialEq)]
pub struct Invocation {
    pub layer: usize,
    pub node: usize,
    /// Input tile `Ŝ^in` (D, H, W, C); `tile_in.c` is `Ĉ`.
    pub tile_in: Shape,
    /// Output tile `Ŝ^out`; `tile_out.c` is `F̂` for conv/fc.
    pub tile_out: Shape,
    /// Runtime kernel `K̂` (conv/pool; `[1,1,1]` otherwise).
    pub kernel: [usize; 3],
    /// Channel groups of the executing layer (depthwise support).
    pub groups: usize,
    /// Scheduled stream counts `ĉ^in`, `ĉ^out` and folding `f̂`.
    pub coarse_in: usize,
    pub coarse_out: usize,
    pub fine: usize,
    /// Partial sums must round-trip off-chip (input channel dim is
    /// folded over multiple invocations).
    pub psum: bool,
    /// Number of full-tile input operands (non-broadcast eltwise = 2).
    pub n_inputs: usize,
    /// Extra input words beyond the full-tile operands: the
    /// broadcast-reduced second operand of a broadcast eltwise (one
    /// per-channel word per tile channel) or the gamma/beta vectors of
    /// a Scale layer (two per channel). Zero for everything else.
    pub extra_in_words: u64,
    /// Executing node's weight wordlength (bits) — scales the weight
    /// word traffic against the 16-bit DMA word unit.
    pub weight_bits: u8,
    /// Executing node's activation wordlength (bits) — scales the
    /// feature-map word traffic.
    pub act_bits: u8,
}

impl Invocation {
    /// Input feature-map words streamed by this invocation: every
    /// full-tile operand plus the broadcast-reduced extras. Weights and
    /// partial sums are accounted separately by the callers.
    pub fn in_words(&self) -> f64 {
        self.tile_in.elems() as f64 * self.n_inputs as f64
            + self.extra_in_words as f64
    }

    /// MACs performed by this invocation (conv/fc).
    pub fn macs(&self) -> u64 {
        (self.tile_out.voxels() * self.tile_out.c
            * self.kernel.iter().product::<usize>()
            * (self.tile_in.c / self.groups).max(1)) as u64
    }

    /// Weight words streamed for this invocation (conv/fc).
    pub fn weight_words(&self) -> u64 {
        (self.kernel.iter().product::<usize>()
            * (self.tile_in.c / self.groups).max(1)
            * self.tile_out.c) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn design_json_round_trips() {
        let m = zoo::c3d_tiny();
        let mut d = Design::initial(&m);
        d.mapping[2] = MapTarget::Fused; // exercise both entry forms
        let text = d.to_json().to_string();
        let back = Design::from_json(
            &crate::util::json::Json::parse(&text).expect("parse"))
            .expect("from_json");
        assert_eq!(back.to_json().to_string(), text);
        assert_eq!(back.nodes, d.nodes);
        assert_eq!(back.mapping, d.mapping);
        // Shape errors carry the "design:" prefix.
        let e = Design::from_json(
            &crate::util::json::Json::parse("{}").expect("parse"));
        assert!(e.unwrap_err().starts_with("design:"));
    }

    #[test]
    fn initial_design_one_node_per_type_and_kernel() {
        let m = zoo::c3d();
        let d = Design::initial(&m);
        assert_eq!(d.validate(&m), Ok(()));
        // C3D: conv[3,3,3], pool[1,2,2], pool[2,2,2], act, fc -> 5.
        assert_eq!(d.nodes.len(), 5);
        assert_eq!(
            d.nodes.iter().filter(|n| n.kind == NodeKind::Pool).count(),
            2
        );
        // Every layer mapped, none fused initially.
        assert!(d.mapping.iter().all(|m| matches!(m, MapTarget::Node(_))));
    }

    #[test]
    fn initial_design_covers_max_dims() {
        let m = zoo::c3d();
        let d = Design::initial(&m);
        let conv = d
            .nodes
            .iter()
            .find(|n| n.kind == NodeKind::Conv)
            .unwrap();
        // conv1a input is the largest conv input: 16x112x112x3, but
        // channel capacity grows to the largest conv Cin = 512.
        assert_eq!(conv.max_in.h, 112);
        assert_eq!(conv.max_in.c, 512);
        assert_eq!(conv.max_filters, 512);
        assert_eq!(conv.max_kernel, [3, 3, 3]);
        // FC capacity: fc6 input 8192.
        let fc = d.nodes.iter().find(|n| n.kind == NodeKind::Fc).unwrap();
        assert_eq!(fc.max_in.c, 8192);
        assert_eq!(fc.max_filters, 4096);
    }

    #[test]
    fn mapping_is_disjoint_and_total() {
        // E(n) partitions M (§V-A): by construction each layer has
        // exactly one target; verify layers_of() sets are disjoint.
        let m = zoo::r2plus1d_18();
        let d = Design::initial(&m);
        let mut seen = vec![false; m.layers.len()];
        for n in 0..d.nodes.len() {
            for l in d.layers_of(n) {
                assert!(!seen[l], "layer {l} mapped twice");
                seen[l] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn validate_rejects_kind_mismatch() {
        let m = zoo::c3d_tiny();
        let mut d = Design::initial(&m);
        // Map a conv layer onto the pool node.
        let pool_node = d
            .nodes
            .iter()
            .position(|n| n.kind == NodeKind::Pool)
            .unwrap();
        d.mapping[0] = MapTarget::Node(pool_node); // layer 0 is conv1
        assert!(d.validate(&m).is_err());
    }

    #[test]
    fn validate_rejects_bad_divisibility() {
        let m = zoo::c3d_tiny();
        let mut d = Design::initial(&m);
        d.nodes[0].coarse_in = 7; // 512 % 7 != 0 (or whatever C_n is)
        if d.nodes[0].max_in.c % 7 != 0 {
            assert!(d.validate(&m).is_err());
        }
    }

    #[test]
    fn compact_removes_orphans() {
        let m = zoo::c3d_tiny();
        let mut d = Design::initial(&m);
        // Move every act layer onto a new node, orphaning nothing;
        // then fuse them all, orphaning the act node.
        for (l, layer) in m.layers.iter().enumerate() {
            if matches!(layer.kind, LayerKind::Activation(_)) {
                d.mapping[l] = MapTarget::Fused;
            }
        }
        let before = d.nodes.len();
        d.compact();
        assert_eq!(d.nodes.len(), before - 1);
        assert_eq!(d.validate(&m), Ok(()));
    }

    #[test]
    fn undo_log_restores_design_exactly() {
        let m = zoo::c3d_tiny();
        let mut d = Design::initial(&m);
        let reference = d.clone();
        let mut log = UndoLog::new();
        log.begin(&d);
        // Mutate a node, remap a layer onto a fresh node, push a node.
        log.save_node(&d, 0);
        d.nodes[0].coarse_in = d.nodes[0].max_in.c;
        d.nodes.push(d.nodes[0]);
        let new_idx = d.nodes.len() - 1;
        log.save_mapping(&d, 0);
        d.mapping[0] = MapTarget::Node(new_idx);
        // Double-save must keep the original snapshot.
        log.save_node(&d, 0);
        log.save_mapping(&d, 0);
        log.undo(&mut d);
        assert_eq!(d.nodes, reference.nodes);
        assert_eq!(d.mapping, reference.mapping);
    }

    #[test]
    fn invocation_macs() {
        let inv = Invocation {
            layer: 0,
            node: 0,
            tile_in: Shape::new(4, 8, 8, 16),
            tile_out: Shape::new(4, 8, 8, 32),
            kernel: [3; 3],
            groups: 1,
            coarse_in: 4,
            coarse_out: 4,
            fine: 1,
            psum: false,
            n_inputs: 1,
            extra_in_words: 0,
            weight_bits: 16,
            act_bits: 16,
        };
        assert_eq!(inv.macs(), (4 * 8 * 8 * 32 * 27 * 16) as u64);
        assert_eq!(inv.weight_words(), (27 * 16 * 32) as u64);
        assert_eq!(inv.in_words(), (4 * 8 * 8 * 16) as f64);
    }
}
