//! Scheduler — Algorithm 1: tile every execution node over its
//! computation node, choosing runtime parameters Γ per invocation.
//!
//! Two forms are produced from the same tiling rules:
//!
//! * `grouped_invocations` — distinct Γ values with multiplicities
//!   (interior tiles are identical, edges differ), used by the SA
//!   optimiser's latency objective. At most 2 sizes per tiled
//!   dimension means ≤ 32 distinct Γ per layer — evaluation is O(1)
//!   in feature-map size.
//! * `build_schedule` — the fully expanded `Φ_G` in NHWDC order, used
//!   by the cycle-approximate simulator and the serving coordinator.
//!
//! With `runtime_params = false` the baseline behaviour of §III-C is
//! modelled: every invocation pads to the node's compile-time maximum
//! (dims *and* kernel), performing the redundant operations the
//! runtime-parameterized hardware avoids (the 18x ablation effect).

use std::collections::HashMap;

use crate::model::layer::{LayerKind, Shape};
use crate::model::ModelGraph;
use crate::perf::{self, BwEnv};
use crate::sdf::{CompNode, Design, Invocation, MapTarget, NodeKind};
use crate::util::math::{ceil_div, max_factor_leq};

/// Scheduling configuration (the ablation toggles of §VII-A1).
#[derive(Debug, Clone, Copy)]
pub struct SchedCfg {
    /// Runtime-parameterized computation nodes (§III-C, Fig 3). Off =
    /// padded execution at the node's compile-time maximum.
    pub runtime_params: bool,
}

impl Default for SchedCfg {
    fn default() -> Self {
        SchedCfg { runtime_params: true }
    }
}

/// Tile size options along one dimension: `floor(L/N)` full tiles of
/// size N plus an optional edge remainder. At most two entries, held
/// inline — the tiling sits on the SA inner loop, where five heap
/// `Vec`s per layer per candidate dominated the evaluation cost.
#[derive(Debug, Clone, Copy)]
struct DimTiles {
    buf: [(usize, u64); 2],
    len: usize,
}

impl DimTiles {
    fn single(size: usize) -> DimTiles {
        DimTiles { buf: [(size, 1), (0, 0)], len: 1 }
    }

    fn as_slice(&self) -> &[(usize, u64)] {
        &self.buf[..self.len]
    }
}

fn dim_tiles(layer_dim: usize, node_dim: usize) -> DimTiles {
    let node_dim = node_dim.max(1);
    let full = layer_dim / node_dim;
    let rem = layer_dim - full * node_dim;
    let mut t = DimTiles { buf: [(0, 0); 2], len: 0 };
    if full > 0 {
        t.buf[t.len] = (node_dim, full as u64);
        t.len += 1;
    }
    if rem > 0 {
        t.buf[t.len] = (rem, 1);
        t.len += 1;
    }
    t
}

/// Effective (kernel, stride, groups, n_inputs) of a layer.
fn layer_geometry(kind: &LayerKind) -> ([usize; 3], [usize; 3], usize, usize) {
    match kind {
        LayerKind::Conv3d { kernel, stride, groups, .. } => {
            (*kernel, *stride, *groups, 1)
        }
        LayerKind::Pool3d { kernel, stride, .. } => (*kernel, *stride, 1, 1),
        LayerKind::Eltwise { broadcast, .. } => {
            ([1; 3], [1; 3], 1, if *broadcast { 1 } else { 2 })
        }
        _ => ([1; 3], [1; 3], 1, 1),
    }
}

/// Output tile dims for an input tile under (kernel-preserving)
/// same-padding semantics: `ceil(tile / stride)` — exact for the
/// stride-1 same-padded and stride==kernel pooling cases that dominate
/// the evaluated models.
fn out_dim(tile: usize, stride: usize) -> usize {
    ceil_div(tile, stride.max(1))
}

/// Visit every grouped Γ of one execution node on its computation node
/// — `(invocation, multiplicity)` pairs (Algorithm 1, lines 4-16) —
/// without materialising a `Vec`. This is the SA latency hot path;
/// `grouped_invocations` is the collecting wrapper for callers that
/// need the list.
pub fn for_each_invocation<F: FnMut(&Invocation, u64)>(
    model: &ModelGraph, design: &Design, layer_idx: usize,
    cfg: &SchedCfg, mut f: F) {
    let MapTarget::Node(node_idx) = design.mapping[layer_idx] else {
        return; // fused layers cost nothing
    };
    let node = &design.nodes[node_idx];
    let layer = &model.layers[layer_idx];
    let (kernel, stride, groups, n_inputs) = layer_geometry(&layer.kind);

    // FC flattens the producer feature-map onto the channel dim.
    let (in_shape, filters) = match &layer.kind {
        LayerKind::Fc { filters } => {
            (Shape::flat(layer.in_shape.elems()), *filters)
        }
        LayerKind::Conv3d { filters, .. } => (layer.in_shape, *filters),
        _ => (layer.in_shape, layer.in_shape.c),
    };

    let is_convlike =
        matches!(node.kind, NodeKind::Conv | NodeKind::Fc);

    let d_t = dim_tiles(in_shape.d, node.max_in.d);
    let h_t = dim_tiles(in_shape.h, node.max_in.h);
    let w_t = dim_tiles(in_shape.w, node.max_in.w);
    let c_t = dim_tiles(in_shape.c, node.max_in.c);
    let f_t = if is_convlike {
        dim_tiles(filters, node.max_filters)
    } else {
        DimTiles::single(filters.min(node.max_in.c))
    };
    let c_folds = ceil_div(in_shape.c, node.max_in.c.max(1));
    let psum = c_folds > 1 && is_convlike
        && !matches!(layer.kind,
                     LayerKind::Conv3d { groups: g, .. } if g > 1);

    for &(td, nd) in d_t.as_slice() {
        for &(th, nh) in h_t.as_slice() {
            for &(tw, nw) in w_t.as_slice() {
                for &(tc, nc) in c_t.as_slice() {
                    for &(tf, nf) in f_t.as_slice() {
                        let mult = nd * nh * nw * nc
                            * if is_convlike { nf } else { 1 };
                        let inv = make_invocation(
                            layer_idx, node_idx, node,
                            Shape::new(td, th, tw, tc), tf, kernel,
                            stride, groups, n_inputs, psum, cfg,
                        );
                        f(&inv, mult);
                    }
                }
            }
        }
    }
}

/// Grouped Γ for one execution node on its computation node:
/// `(invocation, multiplicity)` pairs (Algorithm 1, lines 4-16).
pub fn grouped_invocations(model: &ModelGraph, design: &Design,
                           layer_idx: usize, cfg: &SchedCfg)
    -> Vec<(Invocation, u64)> {
    let mut out = Vec::new();
    for_each_invocation(model, design, layer_idx, cfg,
                        |inv, mult| out.push((inv.clone(), mult)));
    out
}

#[allow(clippy::too_many_arguments)]
fn make_invocation(layer: usize, node_idx: usize, node: &CompNode,
                   tile: Shape, tile_f: usize, kernel: [usize; 3],
                   stride: [usize; 3], groups: usize, n_inputs: usize,
                   psum: bool, cfg: &SchedCfg) -> Invocation {
    if cfg.runtime_params {
        // Runtime-parameterized node: exact tile dims and kernel; the
        // coarse factors are chosen as max{factors Ĉ} within the
        // node's compile-time stream counts (Algorithm 1, lines 9-10).
        let groups_t = groups.min(tile.c).max(1);
        let coarse_in = max_factor_leq(tile.c.max(1), node.coarse_in);
        let (coarse_out, fine) = match node.kind {
            NodeKind::Conv => (
                max_factor_leq(tile_f.max(1), node.coarse_out),
                max_factor_leq(kernel.iter().product::<usize>(),
                               node.fine),
            ),
            NodeKind::Fc => {
                (max_factor_leq(tile_f.max(1), node.coarse_out), 1)
            }
            _ => (coarse_in, 1),
        };
        let tile_out = match node.kind {
            NodeKind::Conv => Shape::new(
                out_dim(tile.d, stride[0]),
                out_dim(tile.h, stride[1]),
                out_dim(tile.w, stride[2]),
                tile_f,
            ),
            NodeKind::Fc => Shape::flat(tile_f),
            NodeKind::Gap => Shape::flat(tile.c),
            NodeKind::Pool => Shape::new(
                out_dim(tile.d, stride[0]),
                out_dim(tile.h, stride[1]),
                out_dim(tile.w, stride[2]),
                tile.c,
            ),
            _ => tile,
        };
        Invocation {
            layer,
            node: node_idx,
            tile_in: tile,
            tile_out,
            kernel,
            groups: groups_t,
            coarse_in,
            coarse_out,
            fine,
            psum,
            n_inputs,
        }
    } else {
        // Baseline: padded execution at compile-time maxima. The node
        // streams its full S_n with kernel K_n; redundant operations
        // included (§VII-A1 "runtime reconfiguration" ablation).
        let tile_in = node.max_in;
        let tile_f_max = node.max_filters;
        let kernel = match node.kind {
            NodeKind::Conv | NodeKind::Pool => node.max_kernel,
            _ => [1; 3],
        };
        let tile_out = match node.kind {
            NodeKind::Conv => Shape::new(
                out_dim(tile_in.d, stride[0]),
                out_dim(tile_in.h, stride[1]),
                out_dim(tile_in.w, stride[2]),
                tile_f_max,
            ),
            NodeKind::Fc => Shape::flat(tile_f_max),
            NodeKind::Gap => Shape::flat(tile_in.c),
            NodeKind::Pool => Shape::new(
                out_dim(tile_in.d, stride[0]),
                out_dim(tile_in.h, stride[1]),
                out_dim(tile_in.w, stride[2]),
                tile_in.c,
            ),
            _ => tile_in,
        };
        Invocation {
            layer,
            node: node_idx,
            tile_in,
            tile_out,
            kernel,
            groups: 1,
            coarse_in: node.coarse_in,
            coarse_out: match node.kind {
                NodeKind::Conv | NodeKind::Fc => node.coarse_out,
                _ => node.coarse_in,
            },
            fine: node.fine,
            psum,
            n_inputs,
        }
    }
}

/// Latency of one execution node across all its invocations (cycles).
/// Allocation-free: the grouped Γ are folded as they are produced, in
/// the same order `grouped_invocations` lists them.
pub fn layer_latency(model: &ModelGraph, design: &Design, layer: usize,
                     env: &BwEnv, cfg: &SchedCfg) -> f64 {
    let kind = match design.mapping[layer] {
        MapTarget::Node(n) => design.nodes[n].kind,
        MapTarget::Fused => return 0.0,
    };
    let mut total = 0.0;
    for_each_invocation(model, design, layer, cfg, |inv, mult| {
        total += perf::latency(kind, inv, env) * mult as f64;
    });
    total
}

/// Memoised [`layer_latency`] for the SA engine: keyed on the pair
/// `(layer, node parameter tuple)`. A layer's latency is a pure
/// function of its own geometry (fixed per run) and the parameters of
/// the computation node it maps to — SA revisits the same node
/// configurations constantly (every rejected move restores one), so
/// the hit rate climbs towards 100% as the annealing cools.
///
/// Values are the bit-exact results of `layer_latency`, so memoised
/// runs are indistinguishable from recomputing ones.
#[derive(Debug, Default)]
pub struct LatencyMemo {
    map: HashMap<(usize, CompNode), f64>,
    pub hits: u64,
    pub misses: u64,
}

impl LatencyMemo {
    pub fn new() -> LatencyMemo {
        LatencyMemo::default()
    }

    pub fn layer_latency(&mut self, model: &ModelGraph, design: &Design,
                         layer: usize, env: &BwEnv, cfg: &SchedCfg)
        -> f64 {
        let node_idx = match design.mapping[layer] {
            MapTarget::Node(n) => n,
            MapTarget::Fused => return 0.0,
        };
        let key = (layer, design.nodes[node_idx]);
        if let Some(&lat) = self.map.get(&key) {
            self.hits += 1;
            return lat;
        }
        self.misses += 1;
        let lat = layer_latency(model, design, layer, env, cfg);
        self.map.insert(key, lat);
        lat
    }
}

/// Total design latency `L_total(G)` — Eq. (2) — in cycles.
pub fn total_latency_cycles(model: &ModelGraph, design: &Design,
                            env: &BwEnv, cfg: &SchedCfg) -> f64 {
    (0..model.layers.len())
        .map(|l| layer_latency(model, design, l, env, cfg))
        .sum()
}

/// The fully expanded schedule `Φ_G` in model (NHWDC) order.
pub fn build_schedule(model: &ModelGraph, design: &Design, cfg: &SchedCfg)
    -> Vec<Invocation> {
    let mut phi = Vec::new();
    for l in 0..model.layers.len() {
        for (inv, mult) in grouped_invocations(model, design, l, cfg) {
            for _ in 0..mult {
                phi.push(inv.clone());
            }
        }
    }
    phi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn env() -> BwEnv {
        BwEnv { bw_in: 24.0, bw_out: 24.0 }
    }

    #[test]
    fn dim_tiles_cover_exactly() {
        for layer_dim in 1..40usize {
            for node_dim in 1..20usize {
                let tiles = dim_tiles(layer_dim, node_dim);
                let covered: u64 = tiles
                    .as_slice()
                    .iter()
                    .map(|&(sz, n)| sz as u64 * n)
                    .sum();
                assert_eq!(covered, layer_dim as u64,
                           "dims {layer_dim}/{node_dim}");
                assert!(tiles
                    .as_slice()
                    .iter()
                    .all(|&(sz, _)| sz <= node_dim));
            }
        }
    }

    #[test]
    fn latency_memo_matches_direct_eval() {
        let m = zoo::c3d_tiny();
        let mut d = Design::initial(&m);
        let cfg = SchedCfg::default();
        let env = env();
        let mut memo = LatencyMemo::new();
        for l in 0..m.layers.len() {
            let direct = layer_latency(&m, &d, l, &env, &cfg);
            let first = memo.layer_latency(&m, &d, l, &env, &cfg);
            let second = memo.layer_latency(&m, &d, l, &env, &cfg);
            assert_eq!(direct.to_bits(), first.to_bits(), "layer {l}");
            assert_eq!(direct.to_bits(), second.to_bits(), "layer {l}");
        }
        assert_eq!(memo.hits, m.layers.len() as u64);
        // A changed node parameter must miss, not alias the old entry.
        let conv = d
            .nodes
            .iter()
            .position(|n| n.kind == NodeKind::Conv)
            .unwrap();
        let misses_before = memo.misses;
        d.nodes[conv].coarse_in = d.nodes[conv].max_in.c;
        let l_conv = d.mapping.iter().position(
            |t| matches!(t, MapTarget::Node(n) if *n == conv)).unwrap();
        let fresh = memo.layer_latency(&m, &d, l_conv, &env, &cfg);
        assert_eq!(fresh.to_bits(),
                   layer_latency(&m, &d, l_conv, &env, &cfg).to_bits());
        assert_eq!(memo.misses, misses_before + 1);
    }

    #[test]
    fn schedule_covers_every_layer_once() {
        let m = zoo::c3d_tiny();
        let d = Design::initial(&m);
        let cfg = SchedCfg::default();
        let phi = build_schedule(&m, &d, &cfg);
        // Warm-start nodes cover each layer's full dims in one or few
        // invocations; every non-fused layer appears at least once.
        for l in 0..m.layers.len() {
            assert!(phi.iter().any(|inv| inv.layer == l), "layer {l}");
        }
    }

    #[test]
    fn grouped_matches_expanded_latency() {
        let m = zoo::c3d_tiny();
        let mut d = Design::initial(&m);
        // Shrink the conv node to force real tiling.
        let conv = d
            .nodes
            .iter_mut()
            .find(|n| n.kind == NodeKind::Conv)
            .unwrap();
        conv.max_in = Shape::new(4, 32, 12, 8);
        conv.max_filters = 16;
        let cfg = SchedCfg::default();
        let env = env();
        let total = total_latency_cycles(&m, &d, &env, &cfg);
        let expanded: f64 = build_schedule(&m, &d, &cfg)
            .iter()
            .map(|inv| {
                let MapTarget::Node(n) = d.mapping[inv.layer] else {
                    unreachable!()
                };
                perf::latency(d.nodes[n].kind, inv, &env)
            })
            .sum();
        assert!((total - expanded).abs() / total < 1e-9);
    }

    #[test]
    fn tiles_respect_node_limits() {
        let m = zoo::c3d();
        let mut d = Design::initial(&m);
        let conv = d
            .nodes
            .iter_mut()
            .find(|n| n.kind == NodeKind::Conv)
            .unwrap();
        conv.max_in = Shape::new(8, 112, 28, 64);
        conv.max_filters = 128;
        let cfg = SchedCfg::default();
        for l in 0..m.layers.len() {
            for (inv, _) in grouped_invocations(&m, &d, l, &cfg) {
                let MapTarget::Node(n) = d.mapping[l] else { continue };
                let node = &d.nodes[n];
                assert!(inv.tile_in.d <= node.max_in.d);
                assert!(inv.tile_in.h <= node.max_in.h);
                assert!(inv.tile_in.w <= node.max_in.w);
                assert!(inv.tile_in.c <= node.max_in.c);
                // Scheduled streams divide the tile channels
                // (constraint 3 of §V-B).
                assert_eq!(inv.tile_in.c % inv.coarse_in, 0);
            }
        }
    }

    #[test]
    fn runtime_params_never_slower() {
        // Padded execution performs a superset of the work.
        let m = zoo::c3d_tiny();
        let d = Design::initial(&m);
        let env = env();
        let rt = total_latency_cycles(&m, &d, &env,
                                      &SchedCfg { runtime_params: true });
        let padded = total_latency_cycles(&m, &d, &env,
                                          &SchedCfg { runtime_params: false });
        assert!(rt <= padded * 1.0001, "rt={rt} padded={padded}");
    }

    #[test]
    fn fused_layers_cost_nothing() {
        let m = zoo::c3d_tiny();
        let mut d = Design::initial(&m);
        let cfg = SchedCfg::default();
        let env = env();
        let before = total_latency_cycles(&m, &d, &env, &cfg);
        let mut act_lat = 0.0;
        for (l, layer) in m.layers.iter().enumerate() {
            if matches!(layer.kind, LayerKind::Activation(_)) {
                act_lat += layer_latency(&m, &d, l, &env, &cfg);
                d.mapping[l] = MapTarget::Fused;
            }
        }
        assert!(act_lat > 0.0);
        let after = total_latency_cycles(&m, &d, &env, &cfg);
        assert!((before - after - act_lat).abs() / before < 1e-9);
    }

    #[test]
    fn total_macs_covered_by_schedule() {
        // The schedule's conv/fc invocations must perform at least the
        // model's MAC count (more when padded).
        let m = zoo::c3d_tiny();
        let d = Design::initial(&m);
        let cfg = SchedCfg::default();
        let phi = build_schedule(&m, &d, &cfg);
        let sched_macs: u64 = phi
            .iter()
            .filter(|inv| {
                let MapTarget::Node(n) = d.mapping[inv.layer] else {
                    return false;
                };
                matches!(d.nodes[n].kind, NodeKind::Conv | NodeKind::Fc)
            })
            .map(|inv| match d.nodes
                [match d.mapping[inv.layer] {
                    MapTarget::Node(n) => n,
                    _ => unreachable!(),
                }]
            .kind
            {
                NodeKind::Fc => (inv.tile_in.c * inv.tile_out.c) as u64,
                _ => inv.macs(),
            })
            .sum();
        assert!(sched_macs >= m.total_macs(),
                "sched {sched_macs} < model {}", m.total_macs());
    }
}
