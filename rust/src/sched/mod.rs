//! Scheduler — Algorithm 1: tile every execution node over its
//! computation node, choosing runtime parameters Γ per invocation.
//!
//! Two forms are produced from the same tiling rules:
//!
//! * `grouped_invocations` — distinct Γ values with multiplicities
//!   (interior tiles are identical, edges differ), used by the SA
//!   optimiser's latency objective. A handful of (tile, output-count)
//!   groups per tiled dimension keeps the distinct Γ per layer O(1)
//!   in feature-map size.
//! * `build_schedule` — the fully expanded `Φ_G` in NHWDC order, used
//!   by the cycle-approximate simulator and the serving coordinator.
//!
//! With `runtime_params = false` the baseline behaviour of §III-C is
//! modelled: every invocation pads to the node's compile-time maximum
//! (dims *and* kernel), performing the redundant operations the
//! runtime-parameterized hardware avoids (the 18x ablation effect).

use std::collections::HashMap;

use crate::model::layer::{LayerKind, Shape};
use crate::model::ModelGraph;
use crate::perf::{self, BwEnv};
use crate::sdf::{CompNode, Design, Invocation, MapTarget, NodeKind};
use crate::util::math::{ceil_div, max_factor_leq};

/// Scheduling configuration (the ablation toggles of §VII-A1).
#[derive(Debug, Clone, Copy)]
pub struct SchedCfg {
    /// Runtime-parameterized computation nodes (§III-C, Fig 3). Off =
    /// padded execution at the node's compile-time maximum.
    pub runtime_params: bool,
}

impl Default for SchedCfg {
    fn default() -> Self {
        SchedCfg { runtime_params: true }
    }
}

/// Tile size options along one dimension: `floor(L/N)` full tiles of
/// size N plus an optional edge remainder. At most two entries, held
/// inline — the tiling sits on the SA inner loop, where five heap
/// `Vec`s per layer per candidate dominated the evaluation cost.
#[derive(Debug, Clone, Copy)]
struct DimTiles {
    buf: [(usize, u64); 2],
    len: usize,
}

impl DimTiles {
    fn single(size: usize) -> DimTiles {
        DimTiles { buf: [(size, 1), (0, 0)], len: 1 }
    }

    fn as_slice(&self) -> &[(usize, u64)] {
        &self.buf[..self.len]
    }
}

fn dim_tiles(layer_dim: usize, node_dim: usize) -> DimTiles {
    let node_dim = node_dim.max(1);
    let full = layer_dim / node_dim;
    let rem = layer_dim - full * node_dim;
    let mut t = DimTiles { buf: [(0, 0); 2], len: 0 };
    if full > 0 {
        t.buf[t.len] = (node_dim, full as u64);
        t.len += 1;
    }
    if rem > 0 {
        t.buf[t.len] = (rem, 1);
        t.len += 1;
    }
    t
}

/// Tile groups along one strided spatial dimension:
/// `(input size, output count, multiplicity)`. Unlike [`DimTiles`],
/// equal-sized input tiles can carry *different* output counts when
/// the tile boundary is not aligned to the stride grid, so up to a
/// handful of groups exist per dimension (still O(1), held inline).
#[derive(Debug, Clone, Copy)]
struct SpatialTiles {
    buf: [(usize, usize, u64); 8],
    len: usize,
}

impl SpatialTiles {
    fn as_slice(&self) -> &[(usize, usize, u64)] {
        &self.buf[..self.len]
    }

    fn push(&mut self, size: usize, out: usize) {
        for e in &mut self.buf[..self.len] {
            if e.0 == size && e.1 == out {
                e.2 += 1;
                return;
            }
        }
        // ≤ 6 distinct (size, out) pairs can occur (two floor/ceil
        // interior counts, one stride-clamped edge, one empty group,
        // the final tile, the remainder). Checked in every profile:
        // the old release fallback silently merged the overflow into
        // the last group, mis-counting invocations — exactly the
        // coverage-corruption class `H3D-020` exists to catch.
        assert!(self.len < self.buf.len(), "spatial group overflow");
        self.buf[self.len] = (size, out, 1);
        self.len += 1;
    }
}

/// Tile one strided spatial dimension, distributing the layer's *true*
/// output count over the tiles. Output `j` anchors at input offset
/// `j*stride` on the global grid, so the tile `[a, a+t)` produces the
/// `ceil((a+t)/s) - ceil(a/s)` outputs anchored inside it; the final
/// tile absorbs any residual outputs whose windows hang into the right
/// padding. Group output counts therefore sum exactly to `out_total`.
///
/// This replaces the old per-tile `ceil(tile/stride)` rule, which was
/// only exact for stride-1 same-padded and stride==kernel tilings and
/// over-counted the outputs of edge/remainder tiles of strided layers
/// (stride-2 convs in X3D, R(2+1)D and SlowOnly), inflating both the
/// modelled output traffic and the MAC count of those tiles.
fn spatial_tiles(layer_dim: usize, node_dim: usize, stride: usize,
                 out_total: usize) -> SpatialTiles {
    let node_dim = node_dim.max(1);
    let stride = stride.max(1);
    let mut t = SpatialTiles { buf: [(0, 0, 0); 8], len: 0 };
    let mut remaining = out_total;
    let mut a = 0usize;
    while a < layer_dim {
        let size = node_dim.min(layer_dim - a);
        let cnt = if a + size >= layer_dim {
            remaining
        } else {
            let anchors =
                ceil_div(a + size, stride) - ceil_div(a, stride);
            anchors.min(remaining)
        };
        remaining -= cnt;
        t.push(size, cnt);
        a += size;
    }
    t
}

/// Effective (kernel, stride, groups, n_inputs, broadcast words per
/// channel) of a layer. `n_inputs` counts full-tile operands only; the
/// last element charges broadcast-reduced side inputs — the per-channel
/// vector operand of a broadcast eltwise (1 word/channel) and the
/// gamma/beta pair of a Scale layer (2 words/channel).
fn layer_geometry(kind: &LayerKind)
    -> ([usize; 3], [usize; 3], usize, usize, usize) {
    match kind {
        LayerKind::Conv3d { kernel, stride, groups, .. } => {
            (*kernel, *stride, *groups, 1, 0)
        }
        LayerKind::Pool3d { kernel, stride, .. } => {
            (*kernel, *stride, 1, 1, 0)
        }
        LayerKind::Eltwise { broadcast, .. } => {
            if *broadcast {
                ([1; 3], [1; 3], 1, 1, 1)
            } else {
                ([1; 3], [1; 3], 1, 2, 0)
            }
        }
        LayerKind::Scale => ([1; 3], [1; 3], 1, 1, 2),
        _ => ([1; 3], [1; 3], 1, 1, 0),
    }
}

/// Output dims of a *padded* execution: the non-runtime hardware emits
/// `ceil(S_n/stride)` positions per invocation regardless of the real
/// window count (redundant operations included — §VII-A1).
fn out_dim_padded(tile: usize, stride: usize) -> usize {
    ceil_div(tile, stride.max(1))
}

/// Visit every grouped Γ of one execution node on its computation node
/// — `(invocation, multiplicity)` pairs (Algorithm 1, lines 4-16) —
/// without materialising a `Vec`. This is the SA latency hot path;
/// `grouped_invocations` is the collecting wrapper for callers that
/// need the list.
pub fn for_each_invocation<F: FnMut(&Invocation, u64)>(
    model: &ModelGraph, design: &Design, layer_idx: usize,
    cfg: &SchedCfg, mut f: F) {
    let MapTarget::Node(node_idx) = design.mapping[layer_idx] else {
        return; // fused layers cost nothing
    };
    let node = &design.nodes[node_idx];
    let layer = &model.layers[layer_idx];
    let (kernel, stride, groups, n_inputs, bcast) =
        layer_geometry(&layer.kind);

    // FC flattens the producer feature-map onto the channel dim.
    let (in_shape, filters) = match &layer.kind {
        LayerKind::Fc { filters } => {
            (Shape::flat(layer.in_shape.elems()), *filters)
        }
        LayerKind::Conv3d { filters, .. } => (layer.in_shape, *filters),
        _ => (layer.in_shape, layer.in_shape.c),
    };

    let is_convlike =
        matches!(node.kind, NodeKind::Conv | NodeKind::Fc);

    // True spatial output dims to distribute over the tiles. Only
    // conv/pool change spatial dims; every other kind maps tiles 1:1.
    let out_sp = match &layer.kind {
        LayerKind::Conv3d { .. } | LayerKind::Pool3d { .. } => [
            layer.out_shape.d, layer.out_shape.h, layer.out_shape.w,
        ],
        _ => [in_shape.d, in_shape.h, in_shape.w],
    };

    let d_t = spatial_tiles(in_shape.d, node.max_in.d, stride[0],
                            out_sp[0]);
    let h_t = spatial_tiles(in_shape.h, node.max_in.h, stride[1],
                            out_sp[1]);
    let w_t = spatial_tiles(in_shape.w, node.max_in.w, stride[2],
                            out_sp[2]);
    let c_t = dim_tiles(in_shape.c, node.max_in.c);
    let f_t = if is_convlike {
        dim_tiles(filters, node.max_filters)
    } else {
        DimTiles::single(filters.min(node.max_in.c))
    };
    let c_folds = ceil_div(in_shape.c, node.max_in.c.max(1));
    let psum = c_folds > 1 && is_convlike
        && !matches!(layer.kind,
                     LayerKind::Conv3d { groups: g, .. } if g > 1);

    for &(td, od, nd) in d_t.as_slice() {
        for &(th, oh, nh) in h_t.as_slice() {
            for &(tw, ow, nw) in w_t.as_slice() {
                for &(tc, nc) in c_t.as_slice() {
                    for &(tf, nf) in f_t.as_slice() {
                        let mult = nd * nh * nw * nc
                            * if is_convlike { nf } else { 1 };
                        let inv = make_invocation(
                            layer_idx, node_idx, node,
                            Shape::new(td, th, tw, tc), [od, oh, ow],
                            tf, kernel, stride, groups, n_inputs, bcast,
                            psum, cfg,
                        );
                        f(&inv, mult);
                    }
                }
            }
        }
    }
}

/// Grouped Γ for one execution node on its computation node:
/// `(invocation, multiplicity)` pairs (Algorithm 1, lines 4-16).
pub fn grouped_invocations(model: &ModelGraph, design: &Design,
                           layer_idx: usize, cfg: &SchedCfg)
    -> Vec<(Invocation, u64)> {
    let mut out = Vec::new();
    for_each_invocation(model, design, layer_idx, cfg,
                        |inv, mult| out.push((inv.clone(), mult)));
    out
}

#[allow(clippy::too_many_arguments)]
fn make_invocation(layer: usize, node_idx: usize, node: &CompNode,
                   tile: Shape, out_sp: [usize; 3], tile_f: usize,
                   kernel: [usize; 3], stride: [usize; 3], groups: usize,
                   n_inputs: usize, bcast: usize, psum: bool,
                   cfg: &SchedCfg) -> Invocation {
    if cfg.runtime_params {
        // Runtime-parameterized node: exact tile dims and kernel; the
        // coarse factors are chosen as max{factors Ĉ} within the
        // node's compile-time stream counts (Algorithm 1, lines 9-10).
        let groups_t = groups.min(tile.c).max(1);
        let coarse_in = max_factor_leq(tile.c.max(1), node.coarse_in);
        let (coarse_out, fine) = match node.kind {
            NodeKind::Conv => (
                max_factor_leq(tile_f.max(1), node.coarse_out),
                max_factor_leq(kernel.iter().product::<usize>(),
                               node.fine),
            ),
            NodeKind::Fc => {
                (max_factor_leq(tile_f.max(1), node.coarse_out), 1)
            }
            _ => (coarse_in, 1),
        };
        let tile_out = match node.kind {
            NodeKind::Conv => {
                Shape::new(out_sp[0], out_sp[1], out_sp[2], tile_f)
            }
            NodeKind::Fc => Shape::flat(tile_f),
            NodeKind::Gap => Shape::flat(tile.c),
            NodeKind::Pool => {
                Shape::new(out_sp[0], out_sp[1], out_sp[2], tile.c)
            }
            _ => tile,
        };
        Invocation {
            layer,
            node: node_idx,
            tile_in: tile,
            tile_out,
            kernel,
            groups: groups_t,
            coarse_in,
            coarse_out,
            fine,
            psum,
            n_inputs,
            extra_in_words: (bcast * tile.c) as u64,
            weight_bits: node.weight_bits,
            act_bits: node.act_bits,
        }
    } else {
        // Baseline: padded execution at compile-time maxima. The node
        // streams its full S_n with kernel K_n; redundant operations
        // included (§VII-A1 "runtime reconfiguration" ablation).
        let tile_in = node.max_in;
        let tile_f_max = node.max_filters;
        let kernel = match node.kind {
            NodeKind::Conv | NodeKind::Pool => node.max_kernel,
            _ => [1; 3],
        };
        let tile_out = match node.kind {
            NodeKind::Conv => Shape::new(
                out_dim_padded(tile_in.d, stride[0]),
                out_dim_padded(tile_in.h, stride[1]),
                out_dim_padded(tile_in.w, stride[2]),
                tile_f_max,
            ),
            NodeKind::Fc => Shape::flat(tile_f_max),
            NodeKind::Gap => Shape::flat(tile_in.c),
            NodeKind::Pool => Shape::new(
                out_dim_padded(tile_in.d, stride[0]),
                out_dim_padded(tile_in.h, stride[1]),
                out_dim_padded(tile_in.w, stride[2]),
                tile_in.c,
            ),
            _ => tile_in,
        };
        Invocation {
            layer,
            node: node_idx,
            tile_in,
            tile_out,
            kernel,
            groups: 1,
            coarse_in: node.coarse_in,
            coarse_out: match node.kind {
                NodeKind::Conv | NodeKind::Fc => node.coarse_out,
                _ => node.coarse_in,
            },
            fine: node.fine,
            psum,
            n_inputs,
            extra_in_words: (bcast * tile_in.c) as u64,
            weight_bits: node.weight_bits,
            act_bits: node.act_bits,
        }
    }
}

/// Latency of one execution node across all its invocations (cycles).
/// Allocation-free: the grouped Γ are folded as they are produced, in
/// the same order `grouped_invocations` lists them.
pub fn layer_latency(model: &ModelGraph, design: &Design, layer: usize,
                     env: &BwEnv, cfg: &SchedCfg) -> f64 {
    let kind = match design.mapping[layer] {
        MapTarget::Node(n) => design.nodes[n].kind,
        MapTarget::Fused => return 0.0,
    };
    let mut total = 0.0;
    for_each_invocation(model, design, layer, cfg, |inv, mult| {
        total += perf::latency(kind, inv, env) * mult as f64;
    });
    total
}

/// Memoised [`layer_latency`] for the SA engine: keyed on the pair
/// `(layer, node parameter tuple)`. A layer's latency is a pure
/// function of its own geometry (fixed per run) and the parameters of
/// the computation node it maps to — SA revisits the same node
/// configurations constantly (every rejected move restores one), so
/// the hit rate climbs towards 100% as the annealing cools.
///
/// Values are the bit-exact results of `layer_latency`, so memoised
/// runs are indistinguishable from recomputing ones.
#[derive(Debug, Default)]
pub struct LatencyMemo {
    map: HashMap<(usize, CompNode), f64>,
    pub hits: u64,
    pub misses: u64,
}

impl LatencyMemo {
    /// Entry cap: long annealing runs on big models (X3D-M: 396
    /// layers, millions of proposals) would otherwise grow the map
    /// without bound — multiplied by K chains per point and the sweep
    /// thread pool. On overflow the map is simply cleared (generation
    /// eviction): values are bit-exact recomputations, so eviction
    /// affects throughput only, never results.
    const MAX_ENTRIES: usize = 1 << 20;

    pub fn new() -> LatencyMemo {
        LatencyMemo::default()
    }

    pub fn layer_latency(&mut self, model: &ModelGraph, design: &Design,
                         layer: usize, env: &BwEnv, cfg: &SchedCfg)
        -> f64 {
        let node_idx = match design.mapping[layer] {
            MapTarget::Node(n) => n,
            MapTarget::Fused => return 0.0,
        };
        let key = (layer, design.nodes[node_idx]);
        if let Some(&lat) = self.map.get(&key) {
            self.hits += 1;
            return lat;
        }
        self.misses += 1;
        let lat = layer_latency(model, design, layer, env, cfg);
        if self.map.len() >= Self::MAX_ENTRIES {
            self.map.clear();
        }
        self.map.insert(key, lat);
        lat
    }
}

/// Total design latency `L_total(G)` — Eq. (2) — in cycles.
pub fn total_latency_cycles(model: &ModelGraph, design: &Design,
                            env: &BwEnv, cfg: &SchedCfg) -> f64 {
    (0..model.layers.len())
        .map(|l| layer_latency(model, design, l, env, cfg))
        .sum()
}

/// The fully expanded schedule `Φ_G` in model (NHWDC) order.
pub fn build_schedule(model: &ModelGraph, design: &Design, cfg: &SchedCfg)
    -> Vec<Invocation> {
    let mut phi = Vec::new();
    for l in 0..model.layers.len() {
        for (inv, mult) in grouped_invocations(model, design, l, cfg) {
            for _ in 0..mult {
                phi.push(inv.clone());
            }
        }
    }
    phi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn env() -> BwEnv {
        BwEnv { bw_in: 24.0, bw_out: 24.0 }
    }

    #[test]
    fn dim_tiles_cover_exactly() {
        for layer_dim in 1..40usize {
            for node_dim in 1..20usize {
                let tiles = dim_tiles(layer_dim, node_dim);
                let covered: u64 = tiles
                    .as_slice()
                    .iter()
                    .map(|&(sz, n)| sz as u64 * n)
                    .sum();
                assert_eq!(covered, layer_dim as u64,
                           "dims {layer_dim}/{node_dim}");
                assert!(tiles
                    .as_slice()
                    .iter()
                    .all(|&(sz, _)| sz <= node_dim));
            }
        }
    }

    #[test]
    fn spatial_tiles_cover_input_and_output_exactly() {
        // Across strides, kernels and paddings: the input sizes must
        // partition the layer dim and the output counts must sum to
        // the layer's true output dim — including unaligned stride-2
        // remainder tiles, which the old ceil(tile/stride) rule
        // over-counted.
        for layer_dim in 1..40usize {
            for node_dim in 1..20usize {
                for stride in 1..4usize {
                    for (k, p) in [(1, 0), (2, 0), (3, 1), (7, 3)] {
                        if k > layer_dim {
                            continue;
                        }
                        let out = (layer_dim + 2 * p - k) / stride + 1;
                        let t = spatial_tiles(layer_dim, node_dim,
                                              stride, out);
                        let (mut cov_in, mut cov_out) = (0u64, 0u64);
                        for &(sz, o, n) in t.as_slice() {
                            assert!(sz <= node_dim);
                            cov_in += sz as u64 * n;
                            cov_out += o as u64 * n;
                        }
                        let ctx = format!(
                            "L={layer_dim} N={node_dim} s={stride} \
                             k={k} p={p}");
                        assert_eq!(cov_in, layer_dim as u64, "{ctx}");
                        assert_eq!(cov_out, out as u64, "{ctx}");
                    }
                }
            }
        }
    }

    #[test]
    fn stride2_remainder_tiles_not_overcounted() {
        // W=15 conv, stride 2, k=3, p=1 -> true out W is 8. Tiled at
        // node width 7 the tiles are [0,7) [7,14) [14,15); the old
        // ceil(tile/stride) rule counted 4+4+1 = 9 output columns.
        use crate::model::graph::{GraphBuilder, INPUT};
        let mut b = GraphBuilder::new("s2", Shape::new(4, 15, 15, 8));
        b.conv("c", INPUT, 8, [3; 3], [1, 2, 2], [1; 3], 1);
        let m = b.finish(0);
        assert_eq!(m.layers[0].out_shape, Shape::new(4, 8, 8, 8));
        let mut d = Design::initial(&m);
        let conv = d
            .nodes
            .iter_mut()
            .find(|n| n.kind == NodeKind::Conv)
            .unwrap();
        conv.max_in.w = 7; // forces the unaligned remainder tiling
        let cfg = SchedCfg::default();
        let out_voxels: u64 = grouped_invocations(&m, &d, 0, &cfg)
            .iter()
            .map(|(inv, mult)| inv.tile_out.voxels() as u64 * mult)
            .sum();
        assert_eq!(out_voxels, (4 * 8 * 8) as u64);
        // And the scheduled MACs match the model exactly (no folding
        // in this design, so equality — not just >=).
        let macs: u64 = grouped_invocations(&m, &d, 0, &cfg)
            .iter()
            .map(|(inv, mult)| inv.macs() * mult)
            .sum();
        assert_eq!(macs, m.total_macs());
    }

    #[test]
    fn broadcast_eltwise_charges_reduced_second_operand() {
        // A broadcast eltwise streams one full tile plus a per-channel
        // vector; a non-broadcast one streams two full tiles.
        use crate::model::graph::{GraphBuilder, INPUT};
        use crate::model::layer::EltOp;
        let build = |broadcast: bool| {
            let mut b =
                GraphBuilder::new("e", Shape::new(2, 4, 4, 16));
            let c1 = b.conv("c1", INPUT, 16, [1; 3], [1; 3], [0; 3], 1);
            let c2 = b.conv("c2", c1, 16, [1; 3], [1; 3], [0; 3], 1);
            let e = b.eltwise("add", c2, c1, EltOp::Add, broadcast);
            let _ = e;
            b.finish(0)
        };
        let cfg = SchedCfg::default();
        for (broadcast, want_extra, want_n) in
            [(true, 16u64, 1usize), (false, 0, 2)]
        {
            let m = build(broadcast);
            let d = Design::initial(&m);
            let invs = grouped_invocations(&m, &d, 2, &cfg);
            assert!(!invs.is_empty());
            for (inv, _) in &invs {
                assert_eq!(inv.n_inputs, want_n, "bcast={broadcast}");
                assert_eq!(inv.extra_in_words, want_extra,
                           "bcast={broadcast}");
            }
            // in_words: full tile(s) + the broadcast vector.
            let full = (2 * 4 * 4 * 16) as f64;
            let want = full * want_n as f64 + want_extra as f64;
            assert_eq!(invs[0].0.in_words(), want, "bcast={broadcast}");
        }
    }

    #[test]
    fn latency_memo_matches_direct_eval() {
        let m = zoo::c3d_tiny();
        let mut d = Design::initial(&m);
        let cfg = SchedCfg::default();
        let env = env();
        let mut memo = LatencyMemo::new();
        for l in 0..m.layers.len() {
            let direct = layer_latency(&m, &d, l, &env, &cfg);
            let first = memo.layer_latency(&m, &d, l, &env, &cfg);
            let second = memo.layer_latency(&m, &d, l, &env, &cfg);
            assert_eq!(direct.to_bits(), first.to_bits(), "layer {l}");
            assert_eq!(direct.to_bits(), second.to_bits(), "layer {l}");
        }
        assert_eq!(memo.hits, m.layers.len() as u64);
        // A changed node parameter must miss, not alias the old entry.
        let conv = d
            .nodes
            .iter()
            .position(|n| n.kind == NodeKind::Conv)
            .unwrap();
        let misses_before = memo.misses;
        d.nodes[conv].coarse_in = d.nodes[conv].max_in.c;
        let l_conv = d.mapping.iter().position(
            |t| matches!(t, MapTarget::Node(n) if *n == conv)).unwrap();
        let fresh = memo.layer_latency(&m, &d, l_conv, &env, &cfg);
        assert_eq!(fresh.to_bits(),
                   layer_latency(&m, &d, l_conv, &env, &cfg).to_bits());
        assert_eq!(memo.misses, misses_before + 1);
    }

    #[test]
    fn schedule_covers_every_layer_once() {
        let m = zoo::c3d_tiny();
        let d = Design::initial(&m);
        let cfg = SchedCfg::default();
        let phi = build_schedule(&m, &d, &cfg);
        // Warm-start nodes cover each layer's full dims in one or few
        // invocations; every non-fused layer appears at least once.
        for l in 0..m.layers.len() {
            assert!(phi.iter().any(|inv| inv.layer == l), "layer {l}");
        }
    }

    #[test]
    fn grouped_matches_expanded_latency() {
        let m = zoo::c3d_tiny();
        let mut d = Design::initial(&m);
        // Shrink the conv node to force real tiling.
        let conv = d
            .nodes
            .iter_mut()
            .find(|n| n.kind == NodeKind::Conv)
            .unwrap();
        conv.max_in = Shape::new(4, 32, 12, 8);
        conv.max_filters = 16;
        let cfg = SchedCfg::default();
        let env = env();
        let total = total_latency_cycles(&m, &d, &env, &cfg);
        let expanded: f64 = build_schedule(&m, &d, &cfg)
            .iter()
            .map(|inv| {
                let MapTarget::Node(n) = d.mapping[inv.layer] else {
                    unreachable!()
                };
                perf::latency(d.nodes[n].kind, inv, &env)
            })
            .sum();
        assert!((total - expanded).abs() / total < 1e-9);
    }

    #[test]
    fn tiles_respect_node_limits() {
        let m = zoo::c3d();
        let mut d = Design::initial(&m);
        let conv = d
            .nodes
            .iter_mut()
            .find(|n| n.kind == NodeKind::Conv)
            .unwrap();
        conv.max_in = Shape::new(8, 112, 28, 64);
        conv.max_filters = 128;
        let cfg = SchedCfg::default();
        for l in 0..m.layers.len() {
            for (inv, _) in grouped_invocations(&m, &d, l, &cfg) {
                let MapTarget::Node(n) = d.mapping[l] else { continue };
                let node = &d.nodes[n];
                assert!(inv.tile_in.d <= node.max_in.d);
                assert!(inv.tile_in.h <= node.max_in.h);
                assert!(inv.tile_in.w <= node.max_in.w);
                assert!(inv.tile_in.c <= node.max_in.c);
                // Scheduled streams divide the tile channels
                // (constraint 3 of §V-B).
                assert_eq!(inv.tile_in.c % inv.coarse_in, 0);
            }
        }
    }

    #[test]
    fn runtime_params_never_slower() {
        // Padded execution performs a superset of the work.
        let m = zoo::c3d_tiny();
        let d = Design::initial(&m);
        let env = env();
        let rt = total_latency_cycles(&m, &d, &env,
                                      &SchedCfg { runtime_params: true });
        let padded = total_latency_cycles(&m, &d, &env,
                                          &SchedCfg { runtime_params: false });
        assert!(rt <= padded * 1.0001, "rt={rt} padded={padded}");
    }

    #[test]
    fn fused_layers_cost_nothing() {
        let m = zoo::c3d_tiny();
        let mut d = Design::initial(&m);
        let cfg = SchedCfg::default();
        let env = env();
        let before = total_latency_cycles(&m, &d, &env, &cfg);
        let mut act_lat = 0.0;
        for (l, layer) in m.layers.iter().enumerate() {
            if matches!(layer.kind, LayerKind::Activation(_)) {
                act_lat += layer_latency(&m, &d, l, &env, &cfg);
                d.mapping[l] = MapTarget::Fused;
            }
        }
        assert!(act_lat > 0.0);
        let after = total_latency_cycles(&m, &d, &env, &cfg);
        assert!((before - after - act_lat).abs() / before < 1e-9);
    }

    #[test]
    fn total_macs_covered_by_schedule() {
        // The schedule's conv/fc invocations must perform at least the
        // model's MAC count (more when padded).
        let m = zoo::c3d_tiny();
        let d = Design::initial(&m);
        let cfg = SchedCfg::default();
        let phi = build_schedule(&m, &d, &cfg);
        let sched_macs: u64 = phi
            .iter()
            .filter(|inv| {
                let MapTarget::Node(n) = d.mapping[inv.layer] else {
                    return false;
                };
                matches!(d.nodes[n].kind, NodeKind::Conv | NodeKind::Fc)
            })
            .map(|inv| match d.nodes
                [match d.mapping[inv.layer] {
                    MapTarget::Node(n) => n,
                    _ => unreachable!(),
                }]
            .kind
            {
                NodeKind::Fc => (inv.tile_in.c * inv.tile_out.c) as u64,
                _ => inv.macs(),
            })
            .sum();
        assert!(sched_macs >= m.total_macs(),
                "sched {sched_macs} < model {}", m.total_macs());
    }
}
