//! HARFLOW3D — a latency-oriented 3D-CNN accelerator toolflow (FCCM'23),
//! reproduced as a Rust + JAX + Pallas three-layer stack.
//!
//! See DESIGN.md for the system inventory and the experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.
pub mod baselines;
pub mod codegen;
pub mod coordinator;
pub mod device;
pub mod fleet;
pub mod model;
pub mod optim;
pub mod perf;
pub mod report;
pub mod resource;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sdf;
pub mod synth;
pub mod tensor;
pub mod util;
