//! HARFLOW3D — a latency-oriented 3D-CNN accelerator toolflow (FCCM'23),
//! reproduced as a Rust + JAX + Pallas three-layer stack.
//!
//! See DESIGN.md for the system inventory and the experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

// CI runs `cargo clippy -- -D warnings`; these stylistic/complexity
// lints fight deliberate patterns in this codebase (index loops over
// split borrows in the SA hot path, NaN-rejecting `!(x > 0.0)` guards,
// result enums sized by their payload, `&Vec` closures over fitted
// coefficient tables) and are allowed crate-wide so the correctness,
// suspicious, and perf lints stay armed.
#![allow(
    clippy::collapsible_else_if,
    clippy::collapsible_if,
    clippy::comparison_chain,
    clippy::large_enum_variant,
    clippy::manual_div_ceil,
    clippy::needless_range_loop,
    clippy::neg_cmp_op_on_partial_ord,
    clippy::new_without_default,
    clippy::or_fun_call,
    clippy::ptr_arg,
    clippy::should_implement_trait,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::useless_format
)]

pub mod baselines;
pub mod check;
pub mod codegen;
pub mod coordinator;
pub mod device;
pub mod fleet;
pub mod model;
pub mod obs;
pub mod optim;
pub mod perf;
pub mod quant;
pub mod report;
pub mod resource;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sdf;
pub mod synth;
pub mod tensor;
pub mod util;
