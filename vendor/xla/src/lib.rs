//! Offline stub for the `xla` PJRT bindings (DESIGN.md §8).
//!
//! The real bindings wrap xla_extension's PJRT CPU client; they are
//! not available in this build environment, so every entry point
//! returns [`Error::Unavailable`]. Callers degrade exactly like a
//! machine without AOT artifacts: `Runtime::load` fails with a clear
//! message and the serving tests skip (they already guard on
//! `artifacts/manifest.json` existing).

use std::fmt;

#[derive(Debug)]
pub enum Error {
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(
            "xla backend not available in this build (offline stub); \
             install the xla_extension bindings to enable PJRT serving",
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error::Unavailable)
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation)
        -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
