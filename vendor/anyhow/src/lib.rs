//! Minimal offline stand-in for the `anyhow` crate — just enough
//! surface for this workspace (`anyhow!`, `Error`, `Result`,
//! `Context`). The build environment has no crates.io access
//! (DESIGN.md §3), so the error type is a plain message string; the
//! call sites only ever format and propagate.

use std::fmt;

/// String-backed error value. Like the real `anyhow::Error`, this type
/// deliberately does NOT implement `std::error::Error`, which is what
/// allows the blanket `From<E: std::error::Error>` conversion below to
/// coexist with the reflexive `From<Error> for Error`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error while propagating it.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a literal (with inline captures), a
/// displayable value, or a format string with arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn macro_forms() {
        let name = "x";
        let a: Error = anyhow!("plain");
        let b: Error = anyhow!("cap {name}");
        let c: Error = anyhow!("{} and {}", 1, 2);
        let d: Error = anyhow!(String::from("owned"));
        assert_eq!(a.to_string(), "plain");
        assert_eq!(b.to_string(), "cap x");
        assert_eq!(c.to_string(), "1 and 2");
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.with_context(|| format!("n={}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "n=3: inner");
    }
}
