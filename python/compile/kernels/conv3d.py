"""Pallas 3D convolution kernel — the toolflow's Conv3D building block.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's FPGA
datapath is a sliding-window generator fed from BRAM line buffers into a
``c_in x c_out x f``-folded DSP dot-product engine. On a TPU-shaped
target the same insight — keep the working tile on-chip, fold the
channel/filter dimensions onto the MAC array — maps to:

* the *tile* the L3 scheduler assigns to an invocation is the Pallas
  block: it lives in VMEM for the whole invocation (the line buffer);
* the kernel im2cols the tile into a ``(Do*Ho*Wo, K^3*Cin)`` patch
  matrix and multiplies it against the ``(K^3*Cin, F_t)`` filter slab
  on the MXU (the DSP array), with the grid iterating over filter
  tiles ``F_t`` (coarse-grain out-folding) so each step's working set
  fits VMEM and Mosaic double-buffers the weight slabs (the paper's
  weight double-buffering);
* ragged tiles at feature-map edges are handled by the L3 scheduler
  exactly as in the paper: runtime-parameterized shapes, realised here
  as per-shape compiled artifacts.

``interpret=True`` always: the CPU PJRT client cannot execute Mosaic
custom-calls; numerics are validated against ``ref.conv3d`` and TPU
performance is estimated analytically (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pick_f_tile(f: int) -> int:
    """Largest filter-tile <= 128 that divides F (MXU lane alignment)."""
    for cand in (128, 64, 32, 16, 8, 4, 2, 1):
        if f % cand == 0:
            return cand
    return 1


def _conv3d_kernel(x_ref, w_ref, b_ref, o_ref, *, kernel, stride, out_shape,
                   activation):
    """One grid step: all output voxels for one filter tile ``F_t``.

    ``x_ref``: ``(Dp, Hp, Wp, Cin)`` pre-padded input tile (whole tile —
    the VMEM-resident line buffer). ``w_ref``: ``(KD, KH, KW, Cin, Ft)``.
    """
    kd, kh, kw = kernel
    jd, jh, jw = stride
    do, ho, wo = out_shape
    x = x_ref[...]
    cin = x.shape[-1]

    # Sliding-window generation: one strided slice per kernel offset.
    # K is a compile-time constant (<= 11 in every supported model), so
    # this unrolls into K^3 slices — the FPGA sliding-window module's
    # tap pattern, expressed as data movement instead of line buffers.
    patches = []
    for dk in range(kd):
        for hk in range(kh):
            for wk in range(kw):
                sl = x[dk:dk + (do - 1) * jd + 1:jd,
                       hk:hk + (ho - 1) * jh + 1:jh,
                       wk:wk + (wo - 1) * jw + 1:jw, :]
                patches.append(sl)
    # (Do, Ho, Wo, K^3 * Cin) -> (Do*Ho*Wo, K^3*Cin)
    pat = jnp.concatenate(patches, axis=-1).reshape(do * ho * wo,
                                                    kd * kh * kw * cin)
    # Filter slab: (KD,KH,KW,Cin,Ft) -> (K^3*Cin, Ft). Axis order must
    # match the patch concat order (kernel offsets outer, channels inner).
    wmat = w_ref[...].reshape(kd * kh * kw * cin, -1)
    acc = jnp.dot(pat, wmat, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][jnp.newaxis, :]
    acc = ref.apply_activation(acc, activation)
    o_ref[...] = acc.reshape(do, ho, wo, -1)


def _dw_conv3d_kernel(x_ref, w_ref, b_ref, o_ref, *, kernel, stride,
                      out_shape, activation):
    """Depth-wise variant: per-channel taps, no cross-channel reduction."""
    kd, kh, kw = kernel
    jd, jh, jw = stride
    do, ho, wo = out_shape
    x = x_ref[...]
    acc = jnp.zeros((do, ho, wo, x.shape[-1]), jnp.float32)
    for dk in range(kd):
        for hk in range(kh):
            for wk in range(kw):
                sl = x[dk:dk + (do - 1) * jd + 1:jd,
                       hk:hk + (ho - 1) * jh + 1:jh,
                       wk:wk + (wo - 1) * jw + 1:jw, :]
                acc = acc + sl * w_ref[dk, hk, wk, :][jnp.newaxis,
                                                      jnp.newaxis,
                                                      jnp.newaxis, :]
    acc = acc + b_ref[...]
    o_ref[...] = ref.apply_activation(acc, activation)


def conv3d(x, w, b=None, stride=(1, 1, 1), padding=(0, 0, 0), groups=1,
           activation=None):
    """Pallas Conv3D building block, matching ``ref.conv3d`` exactly.

    Supports the paper's five convolution flavours: full ``KxKxK``,
    spatial ``1xKxK``, temporal ``Kx1x1``, point-wise ``1x1x1`` and
    depth-wise (``groups == Cin``). Grouped (non-depthwise) convolution
    splits channels and runs one block per group.
    """
    d, h, wd, cin = x.shape
    kd, kh, kw, wcin, f = w.shape
    if b is None:
        b = jnp.zeros((f,), jnp.float32)
    pd, ph, pw = padding
    xp = jnp.pad(x.astype(jnp.float32),
                 [(pd, pd), (ph, ph), (pw, pw), (0, 0)])
    jd, jh, jw = stride
    do = (d + 2 * pd - kd) // jd + 1
    ho = (h + 2 * ph - kh) // jh + 1
    wo = (wd + 2 * pw - kw) // jw + 1

    if groups == cin and wcin == 1:
        # Depth-wise: weights (KD,KH,KW,1,C) -> (KD,KH,KW,C)
        kern = functools.partial(_dw_conv3d_kernel, kernel=(kd, kh, kw),
                                 stride=stride, out_shape=(do, ho, wo),
                                 activation=activation)
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((do, ho, wo, f), jnp.float32),
            interpret=True,
        )(xp, w.reshape(kd, kh, kw, f).astype(jnp.float32),
          b.astype(jnp.float32))

    if groups > 1:
        # Grouped: independent blocks over the channel dimension (the
        # paper's Gr parameter). Cheap static loop — groups is small
        # whenever it is not the depthwise case.
        outs = []
        gc_in = cin // groups
        gc_out = f // groups
        for g in range(groups):
            outs.append(conv3d(
                x[..., g * gc_in:(g + 1) * gc_in],
                w[..., g * gc_out:(g + 1) * gc_out],
                b[g * gc_out:(g + 1) * gc_out],
                stride=stride, padding=padding, groups=1,
                activation=activation))
        return jnp.concatenate(outs, axis=-1)

    ft = _pick_f_tile(f)
    kern = functools.partial(_conv3d_kernel, kernel=(kd, kh, kw),
                             stride=stride, out_shape=(do, ho, wo),
                             activation=activation)
    dp, hp, wp = xp.shape[:3]
    return pl.pallas_call(
        kern,
        grid=(f // ft,),
        in_specs=[
            pl.BlockSpec((dp, hp, wp, cin), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((kd, kh, kw, cin, ft), lambda i: (0, 0, 0, 0, i)),
            pl.BlockSpec((ft,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((do, ho, wo, ft), lambda i: (0, 0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((do, ho, wo, f), jnp.float32),
        interpret=True,
    )(xp, w.astype(jnp.float32), b.astype(jnp.float32))
