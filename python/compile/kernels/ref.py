"""Pure-jnp oracles for every Pallas kernel (L1 correctness ground truth).

All feature-maps are channels-last ``(D, H, W, C)`` — the paper's NHWDC
ordering with the channel dimension fastest-changing (the batch dim is
carried by the caller; the toolflow is latency-oriented, batch == 1).

These functions are the *specification*: the Pallas kernels in this
package must match them to float32 tolerance for every parameter
combination the toolflow can schedule (kernel size, stride, padding,
groups). ``pytest python/tests`` sweeps that space with hypothesis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Convolution
# ---------------------------------------------------------------------------


def conv3d(x, w, b=None, stride=(1, 1, 1), padding=(0, 0, 0), groups=1,
           activation=None):
    """Reference 3D convolution.

    Args:
      x: ``(D, H, W, Cin)`` input feature-map.
      w: ``(KD, KH, KW, Cin // groups, F)`` filters.
      b: optional ``(F,)`` bias.
      stride: ``(JD, JH, JW)``.
      padding: symmetric ``(PD, PH, PW)`` zero padding.
      groups: channel groups (``groups == Cin`` is depthwise).
      activation: ``None | 'relu' | 'sigmoid' | 'swish'`` fused activation.

    Returns:
      ``(Do, Ho, Wo, F)`` output feature-map.
    """
    xb = x[jnp.newaxis]  # NDHWC
    pd, ph, pw = padding
    out = lax.conv_general_dilated(
        xb.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=stride,
        padding=[(pd, pd), (ph, ph), (pw, pw)],
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"),
        feature_group_count=groups,
    )[0]
    if b is not None:
        out = out + b.astype(jnp.float32)
    return apply_activation(out, activation)


# ---------------------------------------------------------------------------
# Pooling
# ---------------------------------------------------------------------------


def pool3d(x, kernel=(2, 2, 2), stride=None, padding=(0, 0, 0), op="max"):
    """Reference 3D max/avg pooling over ``(D, H, W, C)``."""
    if stride is None:
        stride = kernel
    kd, kh, kw = kernel
    jd, jh, jw = stride
    pd, ph, pw = padding
    pads = [(pd, pd), (ph, ph), (pw, pw), (0, 0)]
    x = x.astype(jnp.float32)
    if op == "max":
        init = -jnp.inf
        out = lax.reduce_window(
            x, init, lax.max, (kd, kh, kw, 1), (jd, jh, jw, 1), pads)
    elif op == "avg":
        summed = lax.reduce_window(
            x, 0.0, lax.add, (kd, kh, kw, 1), (jd, jh, jw, 1), pads)
        out = summed / float(kd * kh * kw)
    else:
        raise ValueError(f"unknown pool op {op!r}")
    return out


def global_avg_pool(x):
    """Reference global average pooling: ``(D, H, W, C) -> (C,)``."""
    return jnp.mean(x.astype(jnp.float32), axis=(0, 1, 2))


# ---------------------------------------------------------------------------
# Activation / element-wise
# ---------------------------------------------------------------------------


def apply_activation(x, kind):
    """Apply one of the paper's supported activation types ``T``."""
    if kind is None or kind == "linear":
        return x
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "sigmoid":
        return jax.nn.sigmoid(x)
    if kind == "swish":
        return x * jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation {kind!r}")


def eltwise(a, bx, op="add", broadcast=False):
    """Reference element-wise op with the paper's broadcast mode ``B``.

    In broadcast mode the second operand is a per-channel vector
    ``(C,)`` (the squeeze-excite pattern in X3D), otherwise it has the
    same shape as ``a``.
    """
    a = a.astype(jnp.float32)
    bx = bx.astype(jnp.float32)
    if broadcast:
        bx = bx.reshape((1, 1, 1, -1))
    if op == "add":
        return a + bx
    if op == "mul":
        return a * bx
    raise ValueError(f"unknown eltwise op {op!r}")


# ---------------------------------------------------------------------------
# Fully connected
# ---------------------------------------------------------------------------


def fc(x, w, b=None, activation=None):
    """Reference fully-connected layer: ``(C,) @ (C, F) + (F,)``."""
    out = x.astype(jnp.float32) @ w.astype(jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    return apply_activation(out, activation)
