"""Pallas 3D pooling + global-average-pool building blocks.

The paper's Pool3D node shares the sliding-window front-end with Conv3D
but replaces the dot-product engine with a max/mean reduction tree; the
runtime parameter ``T`` selects the op. Here the window taps are the
same strided slices as in ``conv3d.py`` and the reduction happens in
VREGs. Global average pooling is the dedicated optimised node from
§III-B (a single running mean over the whole tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool3d_kernel(x_ref, o_ref, *, kernel, stride, out_shape, op):
    kd, kh, kw = kernel
    jd, jh, jw = stride
    do, ho, wo = out_shape
    x = x_ref[...]
    acc = None
    for dk in range(kd):
        for hk in range(kh):
            for wk in range(kw):
                sl = x[dk:dk + (do - 1) * jd + 1:jd,
                       hk:hk + (ho - 1) * jh + 1:jh,
                       wk:wk + (wo - 1) * jw + 1:jw, :]
                if acc is None:
                    acc = sl
                elif op == "max":
                    acc = jnp.maximum(acc, sl)
                else:
                    acc = acc + sl
    if op == "avg":
        acc = acc / float(kd * kh * kw)
    o_ref[...] = acc


def pool3d(x, kernel=(2, 2, 2), stride=None, padding=(0, 0, 0), op="max"):
    """Pallas Pool3D building block matching ``ref.pool3d``."""
    if stride is None:
        stride = kernel
    kd, kh, kw = kernel
    jd, jh, jw = stride
    pd, ph, pw = padding
    x = x.astype(jnp.float32)
    if any(padding):
        pad_val = -jnp.inf if op == "max" else 0.0
        x = jnp.pad(x, [(pd, pd), (ph, ph), (pw, pw), (0, 0)],
                    constant_values=pad_val)
    d, h, w, c = x.shape
    do = (d - kd) // jd + 1
    ho = (h - kh) // jh + 1
    wo = (w - kw) // jw + 1
    kern = functools.partial(_pool3d_kernel, kernel=kernel, stride=stride,
                             out_shape=(do, ho, wo), op=op)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((do, ho, wo, c), jnp.float32),
        interpret=True,
    )(x)
    if op == "avg" and any(padding):
        # ref.pool3d divides by the full window size even at padded
        # borders (count_include_pad semantics) — already matched since
        # we padded with zeros and divide by |K|.
        pass
    return out


def _gap_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = jnp.mean(x, axis=(0, 1, 2))


def global_avg_pool(x):
    """Pallas Global-Average-Pool node: ``(D, H, W, C) -> (C,)``."""
    c = x.shape[-1]
    return pl.pallas_call(
        _gap_kernel,
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
