"""Pallas activation, element-wise and fully-connected building blocks.

Activation and Eltwise are the paper's memory-bound nodes (§VII ablation:
fusing them into the preceding Conv removes an off-chip round trip — the
fused path is the ``activation=`` argument of ``conv3d.conv3d``; the
standalone nodes below are the *unfused* baseline the ablation compares
against). FC shares the Conv engine with no feature-map buffering
(§III-B), i.e. a plain VMEM-resident matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _act_kernel(x_ref, o_ref, *, kind):
    o_ref[...] = ref.apply_activation(x_ref[...], kind)


def activation(x, kind="relu"):
    """Standalone Activation node (runtime parameter ``T`` = kind)."""
    return pl.pallas_call(
        functools.partial(_act_kernel, kind=kind),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))


def _eltwise_kernel(a_ref, b_ref, o_ref, *, op, broadcast):
    a = a_ref[...]
    b = b_ref[...]
    if broadcast:
        b = b.reshape((1, 1, 1, -1))
    o_ref[...] = a + b if op == "add" else a * b


def eltwise(a, b, op="add", broadcast=False):
    """Element-wise node (``T`` = op, ``B`` = broadcast mode)."""
    return pl.pallas_call(
        functools.partial(_eltwise_kernel, op=op, broadcast=broadcast),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        interpret=True,
    )(a.astype(jnp.float32), b.astype(jnp.float32))


def _fc_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    acc = jnp.dot(x_ref[...], w_ref[...],
                  preferred_element_type=jnp.float32) + b_ref[...]
    o_ref[...] = ref.apply_activation(acc, activation)


def fc(x, w, b=None, activation=None):
    """Fully-connected node: ``(C,) @ (C, F) + (F,)`` on the MXU."""
    c, f = w.shape
    if b is None:
        b = jnp.zeros((f,), jnp.float32)
    return pl.pallas_call(
        functools.partial(_fc_kernel, activation=activation),
        out_shape=jax.ShapeDtypeStruct((f,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32).reshape(c), w.astype(jnp.float32),
      b.astype(jnp.float32))
