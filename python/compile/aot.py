"""AOT entry point: lower L2/L1 to HLO *text* artifacts for the Rust side.

Run once via ``make artifacts`` (no-op if inputs unchanged); Python never
runs on the request path afterwards.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emitted artifacts (all ``artifacts/*.hlo.txt`` + ``manifest.json``):

* ``layer_<name>`` — one per C3D-tiny layer, Pallas building blocks,
  weights baked as constants. Conv layers take pre-padded inputs.
* ``layer_conv2_tile`` — the runtime-parameterized tile variant: conv2
  executed on an H-halved input tile with halo, proving the schedule's
  tiled invocations compose to the exact full-layer result.
* ``c3d_tiny_ref`` — the golden whole-model forward (pure-jnp oracle).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def padded_in_shape(name, shapes):
    """Input shape of a conv layer *after* coordinator-side padding."""
    prm = model._PARAMS[name]
    (d, h, w, c), _ = shapes[name]
    pd, ph, pw = prm["p"]
    return (d + 2 * pd, h + 2 * ph, w + 2 * pw, c)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    weights = model.make_weights()
    shapes = model.layer_shapes()
    manifest = {"input_shape": list(model.INPUT_SHAPE),
                "num_classes": model.NUM_CLASSES,
                "weight_seed": model.WEIGHT_SEED,
                "layers": [], "artifacts": {}, "weights": {}}

    # Weight binaries ------------------------------------------------------
    # HLO text elides large constants, so weights are runtime parameters
    # of each artifact, exported as raw little-endian f32 and streamed in
    # by the coordinator (the paper's off-chip weight DMA).
    for key, arr in weights.items():
        fname = f"{key}.bin"
        arr.astype("<f4").tofile(os.path.join(args.out_dir, fname))
        manifest["weights"][key] = {"file": fname, "shape": list(arr.shape)}

    def emit(tag, fn, in_shapes):
        specs = [jax.ShapeDtypeStruct(tuple(s), jnp.float32)
                 for s in in_shapes]
        text = lower_fn(fn, *specs)
        fname = f"{tag}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        out_shape = jax.eval_shape(fn, *specs)[0].shape
        manifest["artifacts"][tag] = {
            "file": fname,
            "input_shapes": [list(s) for s in in_shapes],
            "output_shape": list(out_shape),
        }
        print(f"  {fname}: {[tuple(s) for s in in_shapes]} ->"
              f" {tuple(out_shape)} ({len(text)} chars)")
        return out_shape

    # Per-layer artifacts ------------------------------------------------
    for name, kind, prm in model.C3D_TINY:
        fwd = model.layer_pallas(name)
        if kind == "conv":
            in_shapes = [padded_in_shape(name, shapes),
                         weights[name + ".w"].shape,
                         weights[name + ".b"].shape]
            pad = list(prm["p"])
        elif kind == "fc":
            in_shapes = [shapes[name][0], weights[name + ".w"].shape,
                         weights[name + ".b"].shape]
            pad = [0, 0, 0]
        else:
            in_shapes = [shapes[name][0]]
            pad = [0, 0, 0]
        emit(f"layer_{name}", fwd, in_shapes)
        manifest["layers"].append({
            "name": name, "kind": kind, "artifact": f"layer_{name}",
            "pad": pad,
            "weights": ([name + ".w", name + ".b"]
                        if kind in ("conv", "fc") else []),
            "in_shape": list(shapes[name][0]),
            "out_shape": list(shapes[name][1]),
        })

    # Tiled conv2 variant -------------------------------------------------
    # conv2 full padded input is (10, 18, 18, 16) -> out (8, 16, 16, 32).
    # Split the output H dimension into two tiles of 8 rows; each tile
    # needs 10 padded input rows (8 + K_H - 1). The coordinator slices
    # the halo'd rows out of the padded feature-map (DESIGN.md §6).
    (d2, h2, w2, c2) = padded_in_shape("conv2", shapes)
    tile_h_in = 8 + 3 - 1
    emit("layer_conv2_tile", model.layer_pallas("conv2"),
         [(d2, tile_h_in, w2, c2), weights["conv2.w"].shape,
          weights["conv2.b"].shape])
    manifest["conv2_tile"] = {
        "artifact": "layer_conv2_tile",
        "tiles": 2,
        "halo": 1,
        "out_rows_per_tile": 8,
    }

    # Golden whole-model reference ---------------------------------------
    # Weights are parameters here too, in C3D_TINY order (w, b per
    # parametric layer, after the clip input).
    wkeys = [k for name, kind, _ in model.C3D_TINY
             if kind in ("conv", "fc") for k in (name + ".w", name + ".b")]

    def ref_fn(x, *ws):
        wmap = dict(zip(wkeys, ws))
        return (model.ref_forward(x, wmap),)

    emit("c3d_tiny_ref", ref_fn,
         [model.INPUT_SHAPE] + [weights[k].shape for k in wkeys])
    manifest["ref_weight_order"] = wkeys

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
