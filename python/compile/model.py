"""L2 — JAX model definitions for the AOT path.

``C3D_TINY`` is the end-to-end verification network: a scaled-down C3D
(same layer pattern: conv3x3x3 -> pool -> ... -> GAP -> FC) sized so the
whole clip pipeline runs through the CPU PJRT client in seconds. Every
layer has two implementations that must agree at fp32 tolerance:

* ``layer_pallas`` — the L1 Pallas building blocks (what the
  accelerator's computation nodes execute; each layer is AOT-lowered to
  its own HLO artifact so the Rust coordinator can invoke it per
  schedule step);
* ``ref_forward`` — the pure-jnp oracle (lowered once as the golden
  whole-model artifact the coordinator verifies against).

Weights are generated deterministically from ``WEIGHT_SEED`` and baked
into the HLO as constants, so the Rust side needs no weight files.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .kernels import conv3d as kconv
from .kernels import pool3d as kpool
from .kernels import eltwise as kelt
from .kernels import ref

WEIGHT_SEED = 0x3DC33  # deterministic; shared by tests and aot.py

# Input clip: (D, H, W, C) = 8 frames of 32x32 RGB.
INPUT_SHAPE = (8, 32, 32, 3)
NUM_CLASSES = 101  # UCF101

# Layer table for C3D-tiny. Each conv is 3x3x3, stride 1, pad 1, fused
# ReLU (the paper's "fusion of activation into previous layer"
# optimisation — the serving path always uses the fused artifacts; the
# unfused Activation node exists for the ablation benchmarks).
C3D_TINY = [
    # (name, kind, params)
    ("conv1", "conv", dict(cin=3, f=16, k=(3, 3, 3), j=(1, 1, 1),
                           p=(1, 1, 1), act="relu")),
    ("pool1", "pool", dict(k=(1, 2, 2), j=(1, 2, 2), op="max")),
    ("conv2", "conv", dict(cin=16, f=32, k=(3, 3, 3), j=(1, 1, 1),
                           p=(1, 1, 1), act="relu")),
    ("pool2", "pool", dict(k=(2, 2, 2), j=(2, 2, 2), op="max")),
    ("conv3", "conv", dict(cin=32, f=64, k=(3, 3, 3), j=(1, 1, 1),
                           p=(1, 1, 1), act="relu")),
    ("pool3", "pool", dict(k=(2, 2, 2), j=(2, 2, 2), op="max")),
    ("gap", "gap", dict()),
    ("fc", "fc", dict(cin=64, f=NUM_CLASSES)),
]

_KINDS = {name: kind for name, kind, _ in C3D_TINY}
_PARAMS = {name: prm for name, _, prm in C3D_TINY}


def make_weights():
    """Deterministic small-magnitude weights for every parametric layer."""
    rng = np.random.RandomState(WEIGHT_SEED)
    weights = {}
    for name, kind, prm in C3D_TINY:
        if kind == "conv":
            kd, kh, kw = prm["k"]
            shape = (kd, kh, kw, prm["cin"], prm["f"])
            scale = 1.0 / np.sqrt(np.prod(shape[:4]))
            weights[name + ".w"] = (rng.randn(*shape) * scale).astype(
                np.float32)
            weights[name + ".b"] = (rng.randn(prm["f"]) * 0.1).astype(
                np.float32)
        elif kind == "fc":
            shape = (prm["cin"], prm["f"])
            scale = 1.0 / np.sqrt(prm["cin"])
            weights[name + ".w"] = (rng.randn(*shape) * scale).astype(
                np.float32)
            weights[name + ".b"] = (rng.randn(prm["f"]) * 0.1).astype(
                np.float32)
    return weights


def layer_shapes():
    """Propagate shapes through C3D-tiny; returns {name: (in, out)}."""
    shp = INPUT_SHAPE
    out = {}
    for name, kind, prm in C3D_TINY:
        sin = shp
        if kind == "conv":
            kd, kh, kw = prm["k"]
            jd, jh, jw = prm["j"]
            pd, ph, pw = prm["p"]
            d, h, w, _ = shp
            shp = ((d + 2 * pd - kd) // jd + 1,
                   (h + 2 * ph - kh) // jh + 1,
                   (w + 2 * pw - kw) // jw + 1, prm["f"])
        elif kind == "pool":
            kd, kh, kw = prm["k"]
            jd, jh, jw = prm["j"]
            d, h, w, c = shp
            shp = ((d - kd) // jd + 1, (h - kh) // jh + 1,
                   (w - kw) // jw + 1, c)
        elif kind == "gap":
            shp = (shp[-1],)
        elif kind == "fc":
            shp = (prm["f"],)
        out[name] = (sin, shp)
    return out


def layer_pallas(name):
    """Return the Pallas forward fn for one layer.

    Parametric layers (conv/fc) take ``(x, w, b)`` — weights are
    runtime *parameters* of the artifact, streamed in by the Rust
    coordinator exactly as the paper's designs stream weights from
    off-chip memory via DMA (and because HLO text elides large
    constants, so they cannot be baked).

    Conv layers take a *pre-padded* input tile — padding is the Rust
    coordinator's job (it is the DMA/line-buffer behaviour in the
    paper's hardware), which also lets the coordinator reuse one
    artifact for interior and edge tiles of the same padded shape.
    """
    kind = _KINDS[name]
    prm = _PARAMS[name]
    if kind == "conv":
        def fwd(x, w, b):
            # x arrives pre-padded: no further padding here.
            return (kconv.conv3d(x, w, b, stride=prm["j"],
                                 padding=(0, 0, 0),
                                 activation=prm["act"]),)
        return fwd
    if kind == "pool":
        def fwd(x):
            return (kpool.pool3d(x, kernel=prm["k"], stride=prm["j"],
                                 op=prm["op"]),)
        return fwd
    if kind == "gap":
        def fwd(x):
            return (kpool.global_avg_pool(x),)
        return fwd
    if kind == "fc":
        def fwd(x, w, b):
            return (kelt.fc(x, w, b),)
        return fwd
    raise ValueError(f"unknown layer {name}")


def ref_forward(x, weights):
    """Golden whole-model forward using the pure-jnp oracle ops."""
    for name, kind, prm in C3D_TINY:
        if kind == "conv":
            x = ref.conv3d(x, jnp.asarray(weights[name + ".w"]),
                           jnp.asarray(weights[name + ".b"]),
                           stride=prm["j"], padding=prm["p"],
                           activation=prm["act"])
        elif kind == "pool":
            x = ref.pool3d(x, kernel=prm["k"], stride=prm["j"],
                           op=prm["op"])
        elif kind == "gap":
            x = ref.global_avg_pool(x)
        elif kind == "fc":
            x = ref.fc(x, jnp.asarray(weights[name + ".w"]),
                       jnp.asarray(weights[name + ".b"]))
    return x


def pallas_forward(x, weights):
    """Whole-model forward through the Pallas building blocks (padding
    applied here, mirroring what the Rust coordinator does per tile)."""
    for name, kind, prm in C3D_TINY:
        if kind == "conv":
            pd, ph, pw = prm["p"]
            xp = jnp.pad(x, [(pd, pd), (ph, ph), (pw, pw), (0, 0)])
            x = layer_pallas(name)(xp, jnp.asarray(weights[name + ".w"]),
                                   jnp.asarray(weights[name + ".b"]))[0]
        elif kind == "fc":
            x = layer_pallas(name)(x, jnp.asarray(weights[name + ".w"]),
                                   jnp.asarray(weights[name + ".b"]))[0]
        else:
            x = layer_pallas(name)(x)[0]
    return x
