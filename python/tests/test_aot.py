"""AOT pipeline checks: HLO text artifacts are complete, parseable in
the interchange format, and consistent with the manifest."""

import json
import os
import subprocess
import sys

import pytest

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..",
                         "artifacts")


def _manifest():
    path = os.path.join(ARTIFACTS, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_manifest_lists_all_files():
    m = _manifest()
    for tag, meta in m["artifacts"].items():
        path = os.path.join(ARTIFACTS, meta["file"])
        assert os.path.exists(path), f"{tag}: missing {meta['file']}"
        text = open(path).read()
        assert text.startswith("HloModule"), f"{tag}: not HLO text"
        # HLO text must not elide constants (the weights-as-parameters
        # design exists precisely because `constant({...})` does not
        # round-trip).
        assert "constant({...})" not in text, f"{tag}: elided constant"


def test_weight_binaries_match_shapes():
    import numpy as np
    m = _manifest()
    for key, meta in m["weights"].items():
        path = os.path.join(ARTIFACTS, meta["file"])
        data = np.fromfile(path, dtype="<f4")
        assert data.size == np.prod(meta["shape"]), key
        assert np.all(np.isfinite(data)), key


def test_weights_regenerate_identically():
    """The weight seed pins the binaries: regenerating must agree."""
    import numpy as np
    from compile import model
    m = _manifest()
    assert m["weight_seed"] == model.WEIGHT_SEED
    weights = model.make_weights()
    for key, meta in m["weights"].items():
        path = os.path.join(ARTIFACTS, meta["file"])
        data = np.fromfile(path, dtype="<f4")
        np.testing.assert_array_equal(
            data, weights[key].astype("<f4").ravel(), err_msg=key)


def test_layer_chain_covers_model():
    m = _manifest()
    names = [l["name"] for l in m["layers"]]
    assert names == ["conv1", "pool1", "conv2", "pool2", "conv3",
                     "pool3", "gap", "fc"]
    # Chain shapes line up.
    prev = m["input_shape"]
    for l in m["layers"]:
        assert l["in_shape"] == prev, l["name"]
        prev = l["out_shape"]
    assert prev == [m["num_classes"]]


def test_conv2_tile_metadata():
    m = _manifest()
    t = m["conv2_tile"]
    assert t["tiles"] == 2
    art = m["artifacts"][t["artifact"]]
    # Tile input: 8 out rows + (K_h - 1) halo rows = 10.
    assert art["input_shapes"][0][1] == t["out_rows_per_tile"] + 2 * t["halo"]


def test_make_artifacts_is_idempotent():
    """Second `make artifacts` run is a no-op (stamp newer than deps)."""
    repo = os.path.join(os.path.dirname(__file__), "..", "..")
    r = subprocess.run(["make", "-q", "artifacts"], cwd=repo,
                       capture_output=True)
    assert r.returncode == 0, "make artifacts not up to date"
    _ = sys  # keep import (used in debugging variants)
