"""Pallas Conv3D vs pure-jnp oracle — the core L1 correctness signal.

Sweeps the parameter space the toolflow can actually schedule (the five
convolution flavours of §III-B, strides, paddings, groups) both with
explicit paper-relevant cases and a hypothesis sweep over random
shapes/dtypes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import conv3d as kconv
from compile.kernels import ref

RNG = np.random.RandomState(7)


def _rand(shape, dtype=np.float32):
    return RNG.randn(*shape).astype(dtype)


def _check(x, w, b, **kw):
    got = kconv.conv3d(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), **kw)
    want = ref.conv3d(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --- The paper's five convolution flavours (§III-B) ----------------------

def test_full_conv_3x3x3():
    _check(_rand((6, 10, 10, 4)), _rand((3, 3, 3, 4, 8)), _rand((8,)),
           stride=(1, 1, 1), padding=(1, 1, 1))


def test_spatial_conv_1x3x3():
    _check(_rand((4, 9, 9, 6)), _rand((1, 3, 3, 6, 12)), _rand((12,)),
           stride=(1, 1, 1), padding=(0, 1, 1))


def test_temporal_conv_3x1x1():
    _check(_rand((8, 5, 5, 6)), _rand((3, 1, 1, 6, 10)), _rand((10,)),
           stride=(1, 1, 1), padding=(1, 0, 0))


def test_pointwise_conv_1x1x1():
    _check(_rand((4, 6, 6, 16)), _rand((1, 1, 1, 16, 24)), _rand((24,)))


def test_depthwise_conv():
    c = 12
    x = _rand((4, 8, 8, c))
    w = _rand((3, 3, 3, 1, c))
    b = _rand((c,))
    _check(x, w, b, stride=(1, 1, 1), padding=(1, 1, 1), groups=c)


def test_grouped_conv():
    _check(_rand((4, 6, 6, 8)), _rand((3, 3, 3, 4, 8)), _rand((8,)),
           stride=(1, 1, 1), padding=(1, 1, 1), groups=2)


# --- Strides / paddings / fused activations ------------------------------

@pytest.mark.parametrize("stride", [(1, 1, 1), (2, 2, 2), (1, 2, 2),
                                    (2, 1, 1)])
def test_strides(stride):
    _check(_rand((6, 8, 8, 4)), _rand((3, 3, 3, 4, 8)), _rand((8,)),
           stride=stride, padding=(1, 1, 1))


@pytest.mark.parametrize("pad", [(0, 0, 0), (1, 1, 1), (2, 2, 2),
                                 (0, 1, 1), (1, 0, 0)])
def test_paddings(pad):
    _check(_rand((6, 8, 8, 4)), _rand((3, 3, 3, 4, 8)), _rand((8,)),
           stride=(1, 1, 1), padding=pad)


@pytest.mark.parametrize("act", [None, "relu", "sigmoid", "swish"])
def test_fused_activation(act):
    _check(_rand((4, 6, 6, 4)), _rand((3, 3, 3, 4, 8)), _rand((8,)),
           stride=(1, 1, 1), padding=(1, 1, 1), activation=act)


def test_no_bias():
    x = _rand((4, 6, 6, 4))
    w = _rand((3, 3, 3, 4, 8))
    got = kconv.conv3d(jnp.asarray(x), jnp.asarray(w))
    want = ref.conv3d(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_large_filter_count_tiles_mxu():
    # F = 160 forces a non-trivial filter-tile grid (Ft=32, 5 steps).
    _check(_rand((2, 5, 5, 3)), _rand((3, 3, 3, 3, 160)), _rand((160,)),
           stride=(1, 1, 1), padding=(1, 1, 1))


def test_f16_inputs_promote_to_f32():
    x = _rand((4, 6, 6, 4), np.float16)
    w = _rand((3, 3, 3, 4, 8), np.float16)
    b = _rand((8,), np.float16)
    got = kconv.conv3d(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                       padding=(1, 1, 1))
    want = ref.conv3d(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                      padding=(1, 1, 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-2, atol=1e-2)


# --- Hypothesis sweep -----------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(3, 6), h=st.integers(3, 8), w=st.integers(3, 8),
    cin=st.integers(1, 6), f=st.sampled_from([1, 2, 3, 4, 8]),
    kd=st.sampled_from([1, 3]), ks=st.sampled_from([1, 3]),
    jd=st.integers(1, 2), js=st.integers(1, 2),
    pad=st.integers(0, 1),
)
def test_hypothesis_sweep(d, h, w, cin, f, kd, ks, jd, js, pad):
    rng = np.random.RandomState(d * 31 + h * 7 + w)
    x = rng.randn(d, h, w, cin).astype(np.float32)
    wt = rng.randn(kd, ks, ks, cin, f).astype(np.float32)
    b = rng.randn(f).astype(np.float32)
    pd = pad if kd > 1 else 0
    ps = pad if ks > 1 else 0
    # Output dims must be >= 1.
    if (d + 2 * pd - kd) // jd + 1 < 1:
        return
    if (h + 2 * ps - ks) // js + 1 < 1:
        return
    if (w + 2 * ps - ks) // js + 1 < 1:
        return
    _check(x, wt, b, stride=(jd, js, js), padding=(pd, ps, ps))
