"""L2 model consistency: Pallas chain == jnp oracle; shapes; tiling.

The tiled-conv2 test is the python-side proof of the property the Rust
coordinator relies on at serving time: executing a layer as halo'd tile
invocations (the schedule's runtime-parameterized tiles) reproduces the
full-layer output exactly.
"""

import numpy as np

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def test_layer_shapes_chain():
    shapes = model.layer_shapes()
    prev_out = model.INPUT_SHAPE
    for name, kind, _ in model.C3D_TINY:
        sin, sout = shapes[name]
        assert sin == prev_out, f"{name}: shape chain broken"
        prev_out = sout
    assert prev_out == (model.NUM_CLASSES,)


def test_weights_deterministic():
    w1 = model.make_weights()
    w2 = model.make_weights()
    for k in w1:
        np.testing.assert_array_equal(w1[k], w2[k])


def test_pallas_forward_matches_ref():
    weights = model.make_weights()
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(*model.INPUT_SHAPE).astype(np.float32))
    got = model.pallas_forward(x, weights)
    want = model.ref_forward(x, weights)
    assert got.shape == (model.NUM_CLASSES,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_conv2_tiled_equals_full():
    """Two halo'd H-tiles through the tile kernel == full conv2."""
    weights = model.make_weights()
    shapes = model.layer_shapes()
    rng = np.random.RandomState(1)
    (d, h, w, c), _ = shapes["conv2"]
    x = jnp.asarray(rng.randn(d, h, w, c).astype(np.float32))

    prm = model._PARAMS["conv2"]
    pd, ph, pw = prm["p"]
    xp = jnp.pad(x, [(pd, pd), (ph, ph), (pw, pw), (0, 0)])
    wt = jnp.asarray(weights["conv2.w"])
    bt = jnp.asarray(weights["conv2.b"])
    fwd = model.layer_pallas("conv2")
    full = fwd(xp, wt, bt)[0]

    # Tile: out rows [0,8) need padded rows [0,10); out rows [8,16)
    # need padded rows [8,18).
    t0 = fwd(xp[:, 0:10], wt, bt)[0]
    t1 = fwd(xp[:, 8:18], wt, bt)[0]
    tiled = jnp.concatenate([t0, t1], axis=1)
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(full),
                               rtol=1e-4, atol=1e-4)

    want = ref.conv3d(x, jnp.asarray(weights["conv2.w"]),
                      jnp.asarray(weights["conv2.b"]),
                      stride=prm["j"], padding=prm["p"],
                      activation=prm["act"])
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_ref_forward_finite():
    weights = model.make_weights()
    x = jnp.zeros(model.INPUT_SHAPE, jnp.float32)
    out = model.ref_forward(x, weights)
    assert np.all(np.isfinite(np.asarray(out)))
