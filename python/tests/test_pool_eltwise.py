"""Pallas Pool3D / GAP / Activation / Eltwise / FC vs the jnp oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import pool3d as kpool
from compile.kernels import eltwise as kelt
from compile.kernels import ref

RNG = np.random.RandomState(11)


def _rand(shape):
    return RNG.randn(*shape).astype(np.float32)


def _close(got, want, tol=1e-5):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


# --- Pooling --------------------------------------------------------------

@pytest.mark.parametrize("op", ["max", "avg"])
@pytest.mark.parametrize("kernel,stride", [
    ((2, 2, 2), (2, 2, 2)),    # C3D pool2-5
    ((1, 2, 2), (1, 2, 2)),    # C3D pool1 (spatial only)
    ((3, 3, 3), (2, 2, 2)),    # overlapping windows
    ((2, 3, 3), (1, 2, 2)),
])
def test_pool3d(op, kernel, stride):
    x = _rand((6, 9, 9, 5))
    _close(kpool.pool3d(jnp.asarray(x), kernel=kernel, stride=stride, op=op),
           ref.pool3d(jnp.asarray(x), kernel=kernel, stride=stride, op=op))


def test_pool3d_padded_max():
    x = _rand((5, 7, 7, 4))
    _close(kpool.pool3d(jnp.asarray(x), kernel=(3, 3, 3), stride=(2, 2, 2),
                        padding=(1, 1, 1), op="max"),
           ref.pool3d(jnp.asarray(x), kernel=(3, 3, 3), stride=(2, 2, 2),
                      padding=(1, 1, 1), op="max"))


def test_global_avg_pool():
    x = _rand((4, 7, 7, 32))
    _close(kpool.global_avg_pool(jnp.asarray(x)),
           ref.global_avg_pool(jnp.asarray(x)))


@settings(max_examples=20, deadline=None)
@given(d=st.integers(2, 6), h=st.integers(2, 8), c=st.integers(1, 8),
       k=st.integers(1, 3), j=st.integers(1, 2),
       op=st.sampled_from(["max", "avg"]))
def test_pool_hypothesis(d, h, c, k, j, op):
    if (d - k) // j + 1 < 1 or (h - k) // j + 1 < 1:
        return
    rng = np.random.RandomState(d * 13 + h)
    x = rng.randn(d, h, h, c).astype(np.float32)
    _close(kpool.pool3d(jnp.asarray(x), kernel=(k, k, k), stride=(j, j, j),
                        op=op),
           ref.pool3d(jnp.asarray(x), kernel=(k, k, k), stride=(j, j, j),
                      op=op))


# --- Activation / Eltwise ---------------------------------------------------

@pytest.mark.parametrize("kind", ["relu", "sigmoid", "swish"])
def test_activation(kind):
    x = _rand((4, 6, 6, 8))
    _close(kelt.activation(jnp.asarray(x), kind),
           ref.apply_activation(jnp.asarray(x), kind), tol=1e-5)


@pytest.mark.parametrize("op", ["add", "mul"])
@pytest.mark.parametrize("broadcast", [False, True])
def test_eltwise(op, broadcast):
    a = _rand((4, 6, 6, 8))
    b = _rand((8,)) if broadcast else _rand((4, 6, 6, 8))
    _close(kelt.eltwise(jnp.asarray(a), jnp.asarray(b), op=op,
                        broadcast=broadcast),
           ref.eltwise(jnp.asarray(a), jnp.asarray(b), op=op,
                       broadcast=broadcast))


# --- FC ---------------------------------------------------------------------

def test_fc():
    x = _rand((64,))
    w = _rand((64, 101))
    b = _rand((101,))
    _close(kelt.fc(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)),
           ref.fc(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)),
           tol=1e-4)


@pytest.mark.parametrize("act", [None, "relu", "sigmoid"])
def test_fc_activation(act):
    x = _rand((32,))
    w = _rand((32, 16))
    b = _rand((16,))
    _close(kelt.fc(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                   activation=act),
           ref.fc(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                  activation=act), tol=1e-4)
