//! Quickstart: optimise a 3D CNN for an FPGA and inspect the design.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! This is the 60-second tour of the public API: build (or parse) a
//! model, pick a device, run the latency-driven DSE, and look at the
//! resulting accelerator + schedule.

use harflow3d::device;
use harflow3d::model::zoo;
use harflow3d::optim::{self, OptCfg};
use harflow3d::resource::ResourceModel;
use harflow3d::sched::{self, SchedCfg};

fn main() -> anyhow::Result<()> {
    // 1. A model: from the zoo (or onnx::from_json for your own).
    let model = zoo::c3d();
    println!("model: {} — {:.2} GMACs, {:.2} M params, {} layers",
             model.name, model.total_macs() as f64 / 1e9,
             model.total_params() as f64 / 1e6, model.num_layers());

    // 2. A device from the database.
    let dev = device::by_name("zcu102").expect("device");
    println!("device: {} ({}) — {} DSPs, {} BRAM18",
             dev.name, dev.family, dev.avail.dsp, dev.avail.bram);

    // 3. The resource model (fits the LUT/FF regression once).
    let rm = ResourceModel::default_fit();

    // 4. Latency-driven design space exploration (Algorithm 2).
    let result = optim::optimize_multi(&model, &dev, &rm,
                                       OptCfg::default(), 4)
        .map_err(anyhow::Error::msg)?;
    let gops = model.total_macs() as f64 / 1e9
        / (result.latency_ms / 1e3);
    println!("\noptimised design: {:.2} ms/clip  ({:.1} GOps/s, \
              {:.3} GOps/s/DSP)", result.latency_ms, gops,
             gops / result.resources.dsp);
    println!("resources: DSP {:.0} ({:.1}%)  BRAM {:.0} ({:.1}%)",
             result.resources.dsp,
             100.0 * result.resources.dsp / dev.avail.dsp,
             result.resources.bram,
             100.0 * result.resources.bram / dev.avail.bram);

    // 5. The hardware graph G and its schedule Φ_G.
    println!("\ncomputation nodes:");
    for (i, node) in result.design.nodes.iter().enumerate() {
        let layers = result.design.layers_of(i);
        if layers.is_empty() {
            continue;
        }
        println!("  {:>7} node: tile {}x{}x{}x{}, F {}, K {:?}, \
                  c_in {}, c_out {}, f {} — executes {} layers",
                 node.kind.tag(), node.max_in.d, node.max_in.h,
                 node.max_in.w, node.max_in.c, node.max_filters,
                 node.max_kernel, node.coarse_in, node.coarse_out,
                 node.fine, layers.len());
    }
    let phi = sched::build_schedule(&model, &result.design,
                                    &SchedCfg::default());
    println!("schedule: {} runtime-parameterized invocations", phi.len());
    Ok(())
}
