//! Bring-your-own-model: define a 3D CNN with the builder API (or
//! ONNX-JSON), round-trip it through the parser, and map it onto two
//! different boards — the workflow a downstream user follows for a
//! network that is not in the zoo.
//!
//! ```bash
//! cargo run --release --example custom_model
//! ```

use harflow3d::device;
use harflow3d::model::graph::{GraphBuilder, INPUT};
use harflow3d::model::layer::{ActKind, EltOp, PoolOp, Shape};
use harflow3d::model::onnx;
use harflow3d::optim::{self, OptCfg};
use harflow3d::resource::ResourceModel;
use harflow3d::util::json::Json;

/// A little residual 3D CNN for 8x64x64 medical-volume-style inputs —
/// the kind of "future work" domain the paper's conclusion names.
fn build_model() -> harflow3d::model::ModelGraph {
    let mut b = GraphBuilder::new("volnet", Shape::new(8, 64, 64, 1));
    let c1 = b.conv("stem", INPUT, 16, [3, 5, 5], [1, 2, 2], [1, 2, 2], 1);
    let r1 = b.act("stem_relu", c1, ActKind::Relu);

    // Two residual blocks.
    let mut x = r1;
    for i in 0..2 {
        let f = 16 * (i + 1);
        let c = b.conv(&format!("res{i}_a"), x, f, [3; 3], [1; 3], [1; 3], 1);
        let a = b.act(&format!("res{i}_a_relu"), c, ActKind::Relu);
        let c2 = b.conv(&format!("res{i}_b"), a, f, [3; 3], [1; 3],
                        [1; 3], 1);
        let short = if i == 0 {
            x
        } else {
            b.conv(&format!("res{i}_proj"), x, f, [1; 3], [1; 3], [0; 3], 1)
        };
        let add = b.eltwise(&format!("res{i}_add"), c2, short, EltOp::Add,
                            false);
        x = b.act(&format!("res{i}_relu"), add, ActKind::Relu);
        x = b.pool(&format!("pool{i}"), x, PoolOp::Max, [2; 3], [2; 3],
                   [0; 3]);
    }
    let g = b.gap("gap", x);
    b.fc("head", g, 10);
    b.finish(10)
}

fn main() -> anyhow::Result<()> {
    let model = build_model();
    println!("{}: {:.3} GMACs, {} layers ({} conv)", model.name,
             model.total_macs() as f64 / 1e9, model.num_layers(),
             model.num_conv_layers());

    // ONNX-JSON round trip — what `harflow3d export/optimize <file>`
    // do on disk.
    let json_text = onnx::to_json(&model).to_string();
    let parsed = onnx::from_json(&Json::parse(&json_text).unwrap())
        .map_err(anyhow::Error::msg)?;
    assert_eq!(parsed.total_macs(), model.total_macs());
    println!("onnx-json round trip ok ({} bytes)", json_text.len());

    let rm = ResourceModel::default_fit();
    for dev_name in ["zc706", "zcu102"] {
        let dev = device::by_name(dev_name).unwrap();
        let r = optim::optimize_multi(&parsed, &dev, &rm,
                                      OptCfg::default(), 4)
            .map_err(anyhow::Error::msg)?;
        println!("{dev_name}: {:.3} ms/clip, DSP {:.1}%, {} nodes",
                 r.latency_ms,
                 100.0 * r.resources.dsp / dev.avail.dsp,
                 r.design.used_nodes());
    }
    Ok(())
}
