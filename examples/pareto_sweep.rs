//! Device/model sweep: optimise every evaluated 3D CNN for every
//! board and print the latency/accuracy + DSP-efficiency landscape
//! (the data behind Figs 1 and 8).
//!
//! ```bash
//! cargo run --release --example pareto_sweep [--fast]
//! ```

use harflow3d::device;
use harflow3d::model::zoo;
use harflow3d::optim::{self, OptCfg};
use harflow3d::resource::ResourceModel;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let rm = ResourceModel::default_fit();
    println!("{:<14} {:<8} {:>10} {:>10} {:>12} {:>7} {:>7}",
             "model", "device", "lat ms", "GOps/s", "GOps/s/DSP",
             "DSP %", "acc %");
    for model_name in zoo::EVALUATED {
        let model = zoo::by_name(model_name).unwrap();
        let acc = zoo::ucf101_accuracy(model_name).unwrap();
        for dev in device::all_devices() {
            let cfg = if fast { OptCfg::fast(1) } else { OptCfg::default() };
            let n_seeds = if fast { 2 } else { 4 };
            let Ok(r) = optim::optimize_multi(&model, &dev, &rm, cfg,
                                              n_seeds) else {
                println!("{model_name:<14} {:<8} infeasible", dev.name);
                continue;
            };
            let gops = model.total_macs() as f64 / 1e9
                / (r.latency_ms / 1e3);
            println!("{:<14} {:<8} {:>10.2} {:>10.1} {:>12.3} {:>7.1} \
                      {:>7.2}",
                     model_name, dev.name, r.latency_ms, gops,
                     gops / r.resources.dsp,
                     100.0 * r.resources.dsp / dev.avail.dsp, acc);
        }
    }
    Ok(())
}
