//! End-to-end driver (DESIGN.md §6): every layer of the stack on a
//! real small workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving
//! ```
//!
//! 1. **Toolflow** (L3): parse C3D-tiny, SA-optimise it for a ZCU102,
//!    build the runtime-parameterized schedule, and run the
//!    cycle-approximate simulator -> the paper's metric (latency/clip).
//! 2. **Serving** (L3 + PJRT): start the coordinator, stream synthetic
//!    HAR clips through the *numerical* accelerator — every layer
//!    executes its Pallas-lowered HLO artifact (L1/L2), conv2 runs as
//!    two halo'd runtime tiles, and each clip's logits are verified
//!    against the golden whole-model reference artifact.
//!
//! Results are recorded in EXPERIMENTS.md.

use std::path::PathBuf;

use harflow3d::coordinator::{ConvMode, Server};
use harflow3d::device;
use harflow3d::model::zoo;
use harflow3d::optim::{self, OptCfg};
use harflow3d::resource::ResourceModel;
use harflow3d::sched::{self, SchedCfg};
use harflow3d::sim::{self, SimCfg};

fn main() -> anyhow::Result<()> {
    // ---- 1. Toolflow pass ------------------------------------------------
    let model = zoo::c3d_tiny();
    let dev = device::by_name("zcu102").expect("device");
    let rm = ResourceModel::default_fit();
    let r = optim::optimize_multi(&model, &dev, &rm, OptCfg::default(), 4)
        .map_err(anyhow::Error::msg)?;
    let scfg = SchedCfg::default();
    let phi = sched::build_schedule(&model, &r.design, &scfg);
    let srep = sim::simulate(&model, &r.design, &dev, &scfg,
                             &SimCfg::default());
    println!("[toolflow] c3d_tiny @ zcu102: predicted {:.3} ms/clip, \
              simulated {:.3} ms/clip, {} invocations, DSP {:.0}",
             r.latency_ms, srep.ms(&dev), phi.len(), r.resources.dsp);

    // ---- 2. Functional serving over PJRT --------------------------------
    let artifacts = PathBuf::from(
        std::env::var("HARFLOW3D_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".into()));
    let n_clips: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);

    for (mode, label) in [(ConvMode::Whole, "whole-layer"),
                          (ConvMode::Tiled, "tiled-conv2")] {
        let t0 = std::time::Instant::now();
        let server = Server::start(artifacts.clone(), mode, true)?;
        let compile_s = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let m = server.serve_batch(n_clips, 7_000)?;
        let el = t1.elapsed().as_secs_f64();
        println!(
            "[serve/{label}] {} clips: {:.1} clips/s wallclock \
             (mean {:.2} ms, p99 {:.2} ms) | max |err| vs golden \
             {:.2e} | compile {:.1}s",
            m.clips,
            m.clips_per_s(el),
            m.mean_us() / 1e3,
            m.percentile(99.0) as f64 / 1e3,
            m.max_verify_err,
            compile_s,
        );
        assert!(m.max_verify_err < 1e-3,
                "functional verification FAILED");
    }
    println!("[e2e] all clips verified against the golden reference — \
              the three layers compose.");
    Ok(())
}
